//! Cross-node-type filling (paper section V-D, Figure 6).
//!
//! Node-types are processed in decreasing capacity-per-cost order
//! (`sum_d cap(B,d) / cost(B)`). For each node-type B: first its own
//! remaining mapped tasks are placed greedily (purchasing nodes), then
//! every still-unplaced task — regardless of mapping — gets a chance to
//! piggy-back into the leftover capacity of B's nodes, in increasing
//! `h_avg(u|B)` order, never purchasing. Tasks mapped to less
//! cost-effective node-types thus ride along on cheaper capacity.

use crate::model::{Instance, Solution};

use super::penalty_map::h_avg_matrix;
use super::placement::{place_group, select_node, to_solution, FitPolicy, NodeState};

/// Node-type processing order: decreasing capacity per cost. NaN-safe
/// total ordering with a deterministic index tie-break.
pub fn type_order(inst: &Instance) -> Vec<usize> {
    let mut order: Vec<usize> = (0..inst.n_types()).collect();
    order.sort_by(|&a, &b| {
        inst.node_types[b]
            .capacity_per_cost()
            .total_cmp(&inst.node_types[a].capacity_per_cost())
            .then(a.cmp(&b))
    });
    order
}

/// Two-phase solve with cross-node-type filling.
pub fn solve_with_filling(
    inst: &Instance,
    mapping: &[usize],
    policy: FitPolicy,
) -> Solution {
    let m = inst.n_types();
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (u, &b) in mapping.iter().enumerate() {
        groups[b].push(u);
    }
    let mut remaining = vec![true; inst.n_tasks()];
    let mut placed_groups: Vec<Vec<NodeState>> = Vec::with_capacity(m);
    let mut seq = 0usize;
    // h_avg(u|B) for every pair, computed once per solve: the seed
    // re-derived the O(D) aggregate inside the sort comparator below,
    // costing O(n·D·log n) per node-type.
    let h_avg = h_avg_matrix(inst);

    for &b in &type_order(inst) {
        // 1. place this node-type's own still-remaining tasks
        let own: Vec<usize> =
            groups[b].iter().copied().filter(|&u| remaining[u]).collect();
        let mut nodes: Vec<NodeState> = place_group(inst, b, &own, policy, &mut seq);
        for u in &own {
            remaining[*u] = false;
        }

        // 2. piggy-back: all remaining tasks, cheapest-footprint first
        // (cached h_avg key, NaN-safe, deterministic index tie-break)
        let mut rest: Vec<usize> =
            (0..inst.n_tasks()).filter(|&u| remaining[u]).collect();
        rest.sort_by(|&u, &v| {
            h_avg[u * m + b].total_cmp(&h_avg[v * m + b]).then(u.cmp(&v))
        });
        for u in rest {
            if let Some(i) = select_node(inst, &nodes, u, policy) {
                nodes[i].add(inst, u);
                remaining[u] = false;
            }
        }
        placed_groups.push(nodes);
    }
    debug_assert!(remaining.iter().all(|&r| !r), "all tasks placed");
    to_solution(inst, placed_groups)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NodeType, Task};

    #[test]
    fn type_order_by_value() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.1], 0, 0)],
            vec![
                NodeType::new("pricey", vec![1.0], 4.0),  // 0.25 cap/cost
                NodeType::new("value", vec![1.0], 1.0),   // 1.0
                NodeType::new("mid", vec![0.5], 1.0),     // 0.5
            ],
            1,
        );
        assert_eq!(type_order(&inst), vec![1, 2, 0]);
    }

    #[test]
    fn piggyback_avoids_new_node() {
        // Task 1 is mapped to the expensive type but fits in the leftover
        // capacity of the node purchased for task 0 -> only one node bought.
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.5], 0, 1),
                Task::new(1, vec![0.4], 0, 1),
            ],
            vec![
                NodeType::new("value", vec![1.0], 1.0),
                NodeType::new("pricey", vec![1.0], 3.0),
            ],
            2,
        );
        let mapping = vec![0, 1];
        let sol = solve_with_filling(&inst, &mapping, FitPolicy::FirstFit);
        assert!(sol.verify(&inst).is_ok());
        assert_eq!(sol.nodes.len(), 1);
        assert_eq!(sol.nodes[0].type_idx, 0);
        assert!((sol.cost(&inst) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn no_piggyback_when_no_room() {
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.9], 0, 1),
                Task::new(1, vec![0.4], 0, 1),
            ],
            vec![
                NodeType::new("value", vec![1.0], 1.0),
                NodeType::new("pricey", vec![1.0], 3.0),
            ],
            2,
        );
        let sol = solve_with_filling(&inst, &[0, 1], FitPolicy::FirstFit);
        assert!(sol.verify(&inst).is_ok());
        assert_eq!(sol.nodes.len(), 2);
        assert!((sol.cost(&inst) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fill_order_prefers_small_tasks() {
        // leftover space 0.5; two candidates mapped elsewhere: a 0.3 and a
        // 0.4; filling in increasing h_avg places the 0.3 first, then the
        // 0.4 cannot fit — deterministic by the paper's ordering.
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.5], 0, 0),
                Task::new(1, vec![0.4], 0, 0),
                Task::new(2, vec![0.3], 0, 0),
            ],
            vec![
                NodeType::new("value", vec![1.0], 1.0),
                NodeType::new("pricey", vec![1.0], 2.0),
            ],
            1,
        );
        let sol = solve_with_filling(&inst, &[0, 1, 1], FitPolicy::FirstFit);
        assert!(sol.verify(&inst).is_ok());
        // node 0 holds tasks 0 and 2; task 1 forced onto pricey type
        let n0 = &sol.nodes[0];
        assert!(n0.tasks.contains(&0) && n0.tasks.contains(&2));
        assert_eq!(sol.nodes.len(), 2);
    }
}
