//! Dedicated runners for the non-sweep paper artifacts: Figure 1
//! (illustration), Figure 5 (near-integrality), Table I (defaults),
//! section VI-E (running time) and section VI-F (no-timeline factor).

use anyhow::Result;

use crate::algo::lpmap::solve_lp_mapping;
use crate::algo::lowerbound;
use crate::coordinator::planner::Planner;
use crate::io::synth::SynthParams;
use crate::io::workload::WorkloadSpec;
use crate::model::trim;
use crate::util::json::Json;

use super::runner::instantiate;
use super::scenarios;

/// Figure 1: solve the illustration instance both ways. The "best"
/// timeline-agnostic packing is computed exactly (3 tasks — the paper's
/// $16 figure is an optimum, not a heuristic output).
pub fn fig1(planner: &Planner) -> Result<(String, Json)> {
    let inst = scenarios::figure1_instance();
    let row = planner.evaluate(&inst)?;
    let aware_cost = row.algos.iter().map(|a| a.cost).fold(f64::INFINITY, f64::min);

    let collapsed = inst.collapse_timeline();
    let opt = crate::algo::exact::optimal(&collapsed);
    let agnostic_cost = opt.cost(&collapsed);

    let text = format!(
        "== fig1 — illustration (3 tasks, 2 node-types) ==\n\
         timeline-aware   best cost : ${aware_cost:.2}  (paper: $10, one type-1 node)\n\
         timeline-agnostic optimum  : ${agnostic_cost:.2}  (paper: $16, one node of each type)\n"
    );
    let json = Json::obj(vec![
        ("id", Json::Str("fig1".into())),
        ("timeline_aware_cost", Json::Num(aware_cost)),
        ("timeline_agnostic_cost", Json::Num(agnostic_cost)),
    ]);
    Ok((text, json))
}

/// Figure 5: x_max(u) distribution on the paper's sample configuration
/// (n=500, m=10, D=5, T=24).
pub fn fig5(planner: &Planner) -> Result<(String, Json)> {
    let inst = instantiate(&WorkloadSpec::parse("synth:n=500")?, 1)?;
    let tr = trim(&inst).instance;
    let (solver, backend) = planner.solver_for(&tr);
    let outcome = solve_lp_mapping(&tr, solver.as_ref())?;
    let mut xs = outcome.x_max.clone();
    xs.sort_by(f64::total_cmp);

    let n = xs.len() as f64;
    let frac_ge = |t: f64| xs.iter().filter(|&&v| v >= t).count() as f64 / n;
    let text = format!(
        "== fig5 — near-integrality of the LP solution (n=500, m=10, D=5, T=24) ==\n\
         backend: {backend}\n\
         x_max >= 0.99 : {:5.1}% of tasks\n\
         x_max >= 0.9  : {:5.1}% of tasks\n\
         x_max >= 0.5  : {:5.1}% of tasks\n\
         min x_max     : {:.3}   (1/m floor = {:.3})\n\
         series (sorted, deciles): {}\n",
        frac_ge(0.99) * 100.0,
        frac_ge(0.9) * 100.0,
        frac_ge(0.5) * 100.0,
        xs.first().copied().unwrap_or(0.0),
        1.0 / tr.n_types() as f64,
        (0..=10)
            .map(|i| format!("{:.2}", xs[(i * (xs.len() - 1)) / 10]))
            .collect::<Vec<_>>()
            .join(" "),
    );
    let json = Json::obj(vec![
        ("id", Json::Str("fig5".into())),
        ("backend", Json::Str(backend.to_string())),
        ("x_max_sorted", Json::arr_f64(&xs)),
        ("frac_ge_0.9", Json::Num(frac_ge(0.9))),
    ]);
    Ok((text, json))
}

/// Table I: the defaults table.
pub fn tab1() -> (String, Json) {
    let p = SynthParams::default();
    let text = format!(
        "== tab1 — default parameter values (paper Table I) ==\n\
         n (tasks)         both        {}\n\
         m (node-types)    both        {}\n\
         T (timeslots)     synthetic   {}\n\
         capacity          synthetic   [{}, {}]\n\
         demand            synthetic   [{}, {}]\n\
         D (dimensions)    synthetic   {}\n",
        p.n, p.m, p.horizon, p.cap_range.0, p.cap_range.1, p.dem_range.0, p.dem_range.1, p.dims
    );
    let json = Json::obj(vec![
        ("id", Json::Str("tab1".into())),
        ("n", Json::Num(p.n as f64)),
        ("m", Json::Num(p.m as f64)),
        ("t", Json::Num(p.horizon as f64)),
        ("dims", Json::Num(p.dims as f64)),
    ]);
    (text, json)
}

/// Section VI-E: running-time profile on the largest GCT configuration.
pub fn running_time(planner: &Planner, quick: bool) -> Result<(String, Json)> {
    let n = if quick { 500 } else { 2000 };
    let inst = instantiate(&WorkloadSpec::parse(&format!("gct:n={n},m=13,priced"))?, 1)?;
    // sequential fold: per-algorithm seconds must be uncontended here
    let row = planner.evaluate_sequential(&inst)?;
    let mut text = format!(
        "== rt — running time, GCT-like n={n}, m=13 (paper section VI-E) ==\n\
         backend          : {}\n",
        row.backend_used
    );
    for a in &row.algos {
        text.push_str(&format!(
            "         {:<17}: {:7.2}s   ({})\n",
            a.label,
            a.seconds,
            a.stages
                .iter()
                .map(|s| format!("{} {:.2}s", s.stage, s.seconds))
                .collect::<Vec<_>>()
                .join(", ")
        ));
    }
    text.push_str(&format!("         lower bound extra: {:7.3}s\n", row.lb_seconds));
    // per-algorithm wall seconds + the LB extra, in portfolio order
    let mut seconds: Vec<f64> = row.algos.iter().map(|a| a.seconds).collect();
    seconds.push(row.lb_seconds);
    let json = Json::obj(vec![
        ("id", Json::Str("rt".into())),
        ("n", Json::Num(n as f64)),
        ("seconds", Json::arr_f64(&seconds)),
        ("backend", Json::Str(row.backend_used.to_string())),
    ]);
    Ok((text, json))
}

/// Section VI-F: the no-timeline cost factor (~2x in the paper).
pub fn no_timeline(planner: &Planner, quick: bool) -> Result<(String, Json)> {
    let seeds: Vec<u64> = if quick { vec![1] } else { vec![1, 2, 3] };
    let mut factors = Vec::new();
    let spec = WorkloadSpec::parse("gct:n=1000,m=10")?;
    for &seed in &seeds {
        let inst = instantiate(&spec, seed)?;
        // timeline-aware LP-map-F cost
        let row = planner.evaluate(&inst)?;
        let aware = row.get("LP-map-F").expect("preset portfolio").cost;
        // timeline-agnostic *lower bound* (paper compares against an LB)
        let collapsed = trim(&inst.collapse_timeline()).instance;
        let (solver, _) = planner.solver_for(&collapsed);
        let lb = lowerbound::lower_bound(&collapsed, solver.as_ref())?.best();
        factors.push(lb / aware);
    }
    let mean = crate::util::stats::mean(&factors);
    let text = format!(
        "== ntl — no-timeline comparison (paper section VI-F) ==\n\
         timeline-agnostic LB / timeline-aware LP-map-F cost per seed: {}\n\
         mean factor: {mean:.2}x   (paper reports ~2x)\n",
        factors.iter().map(|f| format!("{f:.2}x")).collect::<Vec<_>>().join(" "),
    );
    let json = Json::obj(vec![
        ("id", Json::Str("ntl".into())),
        ("factors", Json::arr_f64(&factors)),
        ("mean_factor", Json::Num(mean)),
    ]);
    Ok((text, json))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Backend;

    #[test]
    fn tab1_renders() {
        let (text, json) = tab1();
        assert!(text.contains("1000"));
        assert_eq!(json.get("n").as_usize(), Some(1000));
    }

    #[test]
    fn fig1_reproduces_paper_numbers() {
        let planner = Planner::new(Backend::Native).unwrap();
        let (text, json) = fig1(&planner).unwrap();
        assert!(text.contains("$10.00"), "{text}");
        assert_eq!(json.get("timeline_aware_cost").as_f64(), Some(10.0));
        assert_eq!(json.get("timeline_agnostic_cost").as_f64(), Some(16.0));
    }
}
