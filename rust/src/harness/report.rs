//! Report formatting: paper-style tables on stdout + JSON result files
//! for EXPERIMENTS.md.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::stats::Summary;

use super::runner::FigureResult;

fn fmt_summary(s: &Summary) -> String {
    format!("{:.3}±{:.3}", s.mean, s.std)
}

/// Render one figure as an aligned text table (normalized costs,
/// mean ± std over seeds; 1.000 = LP lower bound).
pub fn render_table(res: &FigureResult) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {} — {} ==\n", res.id, res.title));
    out.push_str(&format!(
        "{:<14} {:>14} {:>14} {:>14} {:>14} {:>12} {:>10}\n",
        res.x_name, "PenaltyMap", "PenaltyMap-F", "LP-map", "LP-map-F", "LB(abs)", "backend"
    ));
    for row in &res.rows {
        out.push_str(&format!(
            "{:<14} {:>14} {:>14} {:>14} {:>14} {:>12.3} {:>10}\n",
            row.label,
            fmt_summary(&row.normalized[0]),
            fmt_summary(&row.normalized[1]),
            fmt_summary(&row.normalized[2]),
            fmt_summary(&row.normalized[3]),
            row.lower_bound.mean,
            row.backend,
        ));
    }
    // paper-style gain lines
    if !res.rows.is_empty() {
        let max_gain = res
            .rows
            .iter()
            .map(|r| (r.normalized[0].mean - r.normalized[3].mean) / r.normalized[3].mean)
            .fold(f64::NEG_INFINITY, f64::max);
        let worst_lpf = res
            .rows
            .iter()
            .map(|r| r.normalized[3].mean)
            .fold(f64::NEG_INFINITY, f64::max);
        out.push_str(&format!(
            "-- LP-map-F vs PenaltyMap: up to {:.0}% cheaper; LP-map-F stays within {:.0}% of LB\n",
            max_gain * 100.0,
            (worst_lpf - 1.0) * 100.0
        ));
    }
    out
}

fn summary_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("mean", Json::Num(s.mean)),
        ("std", Json::Num(s.std)),
        ("min", Json::Num(s.min)),
        ("max", Json::Num(s.max)),
        ("n", Json::Num(s.n as f64)),
    ])
}

pub fn to_json(res: &FigureResult) -> Json {
    Json::obj(vec![
        ("id", Json::Str(res.id.clone())),
        ("title", Json::Str(res.title.clone())),
        ("x_name", Json::Str(res.x_name.clone())),
        (
            "rows",
            Json::Arr(
                res.rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("label", Json::Str(r.label.clone())),
                            ("penalty_map", summary_json(&r.normalized[0])),
                            ("penalty_map_f", summary_json(&r.normalized[1])),
                            ("lp_map", summary_json(&r.normalized[2])),
                            ("lp_map_f", summary_json(&r.normalized[3])),
                            ("lower_bound", summary_json(&r.lower_bound)),
                            ("seconds", Json::arr_f64(&r.seconds)),
                            ("backend", Json::Str(r.backend.to_string())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write `<dir>/<id>.json`.
pub fn save_json(res: &FigureResult, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{}.json", res.id));
    std::fs::write(&path, to_json(res).to_string())
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::runner::Row;

    fn sample() -> FigureResult {
        FigureResult {
            id: "figX".into(),
            title: "test".into(),
            x_name: "m".into(),
            rows: vec![Row {
                label: "m=5".into(),
                normalized: [
                    Summary::of(&[1.4, 1.5]),
                    Summary::of(&[1.3, 1.4]),
                    Summary::of(&[1.2, 1.3]),
                    Summary::of(&[1.1, 1.2]),
                ],
                lower_bound: Summary::of(&[10.0, 11.0]),
                seconds: [0.1, 0.1, 0.5, 0.5, 0.0],
                backend: "pdhg-native",
            }],
        }
    }

    #[test]
    fn table_renders() {
        let t = render_table(&sample());
        assert!(t.contains("PenaltyMap-F"));
        assert!(t.contains("m=5"));
        assert!(t.contains("LP-map-F"));
    }

    #[test]
    fn json_roundtrip() {
        let j = to_json(&sample());
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("id").as_str(), Some("figX"));
        let rows = parsed.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].get("lp_map_f").get("mean").as_f64().unwrap() > 1.0);
    }

    #[test]
    fn save_writes_file() {
        let dir = std::env::temp_dir().join(format!("tlrs_report_{}", std::process::id()));
        save_json(&sample(), &dir).unwrap();
        assert!(dir.join("figX.json").exists());
    }
}
