//! Report formatting: paper-style tables on stdout + JSON result files
//! for EXPERIMENTS.md.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::stats::Summary;

use super::runner::FigureResult;

fn fmt_summary(s: &Summary) -> String {
    format!("{:.3}±{:.3}", s.mean, s.std)
}

/// Render one figure as an aligned text table (normalized costs,
/// mean ± std over seeds; 1.000 = LP lower bound). Columns follow the
/// rows' label-keyed algorithm set — any pipeline portfolio renders.
pub fn render_table(res: &FigureResult) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {} — {} ==\n", res.id, res.title));
    let algos: &[String] = res.rows.first().map(|r| r.algos.as_slice()).unwrap_or(&[]);
    out.push_str(&format!("{:<14}", res.x_name));
    for a in algos {
        out.push_str(&format!(" {a:>14}"));
    }
    out.push_str(&format!(" {:>12} {:>10}\n", "LB(abs)", "backend"));
    for row in &res.rows {
        out.push_str(&format!("{:<14}", row.label));
        for s in &row.normalized {
            out.push_str(&format!(" {:>14}", fmt_summary(s)));
        }
        out.push_str(&format!(" {:>12.3} {:>10}\n", row.lower_bound.mean, row.backend));
    }
    // paper-style gain lines (when both headline algorithms are present)
    let has = |label: &str| res.rows.iter().all(|r| r.get(label).is_some());
    if !res.rows.is_empty() && has("PenaltyMap") && has("LP-map-F") {
        let max_gain = res
            .rows
            .iter()
            .map(|r| {
                let pen = r.get("PenaltyMap").unwrap().mean;
                let lpf = r.get("LP-map-F").unwrap().mean;
                (pen - lpf) / lpf
            })
            .fold(f64::NEG_INFINITY, f64::max);
        let worst_lpf = res
            .rows
            .iter()
            .map(|r| r.get("LP-map-F").unwrap().mean)
            .fold(f64::NEG_INFINITY, f64::max);
        out.push_str(&format!(
            "-- LP-map-F vs PenaltyMap: up to {:.0}% cheaper; LP-map-F stays within {:.0}% of LB\n",
            max_gain * 100.0,
            (worst_lpf - 1.0) * 100.0
        ));
    }
    out
}

fn summary_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("mean", Json::Num(s.mean)),
        ("std", Json::Num(s.std)),
        ("min", Json::Num(s.min)),
        ("max", Json::Num(s.max)),
        ("n", Json::Num(s.n as f64)),
    ])
}

/// Stable JSON key for an algorithm display label. The four paper
/// presets keep their historical keys; other labels are sanitized.
pub fn json_key(label: &str) -> String {
    match label {
        "PenaltyMap" => "penalty_map".into(),
        "PenaltyMap-F" => "penalty_map_f".into(),
        "LP-map" => "lp_map".into(),
        "LP-map-F" => "lp_map_f".into(),
        other => other
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect(),
    }
}

pub fn to_json(res: &FigureResult) -> Json {
    Json::obj(vec![
        ("id", Json::Str(res.id.clone())),
        ("title", Json::Str(res.title.clone())),
        ("x_name", Json::Str(res.x_name.clone())),
        (
            "rows",
            Json::Arr(
                res.rows
                    .iter()
                    .map(|r| {
                        let mut obj = std::collections::BTreeMap::new();
                        obj.insert("label".to_string(), Json::Str(r.label.clone()));
                        obj.insert(
                            "algorithms".to_string(),
                            Json::Arr(r.algos.iter().map(|a| Json::Str(a.clone())).collect()),
                        );
                        obj.insert("lower_bound".to_string(), summary_json(&r.lower_bound));
                        obj.insert("seconds".to_string(), Json::arr_f64(&r.seconds));
                        // sweeps race the portfolio, so per-algorithm
                        // seconds are contended wall times (see Row)
                        obj.insert(
                            "timing".to_string(),
                            Json::Str("parallel-race".into()),
                        );
                        obj.insert("lb_seconds".to_string(), Json::Num(r.lb_seconds));
                        obj.insert("backend".to_string(), Json::Str(r.backend.to_string()));
                        // algorithm keys last, deduplicated against the
                        // structural keys above and each other: two labels
                        // sanitizing identically must not drop a column
                        for (a, s) in r.algos.iter().zip(&r.normalized) {
                            let base = json_key(a);
                            let mut key = base.clone();
                            let mut n = 2;
                            while obj.contains_key(&key) {
                                key = format!("{base}_{n}");
                                n += 1;
                            }
                            obj.insert(key, summary_json(s));
                        }
                        Json::Obj(obj)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write `<dir>/<id>.json`.
pub fn save_json(res: &FigureResult, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{}.json", res.id));
    std::fs::write(&path, to_json(res).to_string())
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::runner::Row;

    fn sample() -> FigureResult {
        FigureResult {
            id: "figX".into(),
            title: "test".into(),
            x_name: "m".into(),
            rows: vec![Row {
                label: "m=5".into(),
                algos: vec![
                    "PenaltyMap".into(),
                    "PenaltyMap-F".into(),
                    "LP-map".into(),
                    "LP-map-F".into(),
                ],
                normalized: vec![
                    Summary::of(&[1.4, 1.5]),
                    Summary::of(&[1.3, 1.4]),
                    Summary::of(&[1.2, 1.3]),
                    Summary::of(&[1.1, 1.2]),
                ],
                lower_bound: Summary::of(&[10.0, 11.0]),
                seconds: vec![0.1, 0.1, 0.5, 0.5],
                lb_seconds: 0.01,
                backend: "pdhg-native",
            }],
        }
    }

    #[test]
    fn table_renders() {
        let t = render_table(&sample());
        assert!(t.contains("PenaltyMap-F"));
        assert!(t.contains("m=5"));
        assert!(t.contains("LP-map-F"));
    }

    #[test]
    fn json_roundtrip() {
        let j = to_json(&sample());
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("id").as_str(), Some("figX"));
        let rows = parsed.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].get("lp_map_f").get("mean").as_f64().unwrap() > 1.0);
    }

    #[test]
    fn colliding_labels_keep_every_column() {
        let mut res = sample();
        // "lp fill ls" and "lp+fill+ls" both sanitize to lp_fill_ls;
        // "backend" collides with a structural key
        res.rows[0].algos = vec![
            "lp fill ls".into(),
            "lp+fill+ls".into(),
            "backend".into(),
            "LP-map-F".into(),
        ];
        let parsed = crate::util::json::parse(&to_json(&res).to_string()).unwrap();
        let row = &parsed.get("rows").as_arr().unwrap()[0];
        assert!(row.get("lp_fill_ls").get("mean").as_f64().is_some());
        assert!(row.get("lp_fill_ls_2").get("mean").as_f64().is_some());
        // the structural backend string survives; the algo got a suffix
        assert!(row.get("backend").as_str().is_some());
        assert!(row.get("backend_2").get("mean").as_f64().is_some());
        assert!(row.get("lp_map_f").get("mean").as_f64().is_some());
    }

    #[test]
    fn save_writes_file() {
        let dir = std::env::temp_dir().join(format!("tlrs_report_{}", std::process::id()));
        save_json(&sample(), &dir).unwrap();
        assert!(dir.join("figX.json").exists());
    }
}
