//! Scenario definitions for every figure/table in the paper's evaluation
//! (DESIGN.md section 5 maps each id to the paper artifact).
//!
//! Every figure point names its workload with an `io::workload` spec
//! string, parsed by the same parser the CLI `--workload` flag and the
//! service JSON API use — figures are just another spec consumer.

use crate::coordinator::config::default_seeds;
use crate::io::workload::WorkloadSpec;
use crate::model::{Instance, NodeType, Task};

/// One figure data point (x-axis value), evaluated over several seeds.
#[derive(Clone, Debug)]
pub struct Point {
    pub label: String,
    pub workload: WorkloadSpec,
}

/// A figure: an ordered list of points plus presentation metadata.
#[derive(Clone, Debug)]
pub struct Figure {
    pub id: &'static str,
    pub title: &'static str,
    pub x_name: &'static str,
    pub points: Vec<Point>,
    pub seeds: Vec<u64>,
}

/// Parse a figure workload spec (figure definitions are code, so a bad
/// spec is a programmer error worth failing loudly on).
fn w(spec: &str) -> WorkloadSpec {
    WorkloadSpec::parse(spec).unwrap_or_else(|e| panic!("figure spec '{spec}': {e:#}"))
}

fn point(label: String, spec: &str) -> Point {
    Point { label, workload: w(spec) }
}

/// All figure ids, in paper order.
pub fn all_ids() -> Vec<&'static str> {
    vec!["fig1", "fig5", "fig7a", "fig7b", "fig7c", "fig8a", "fig8b", "fig9",
         "fig10", "fig11", "tab1", "rt", "ntl"]
}

/// Build the sweep for a figure id handled by the generic runner
/// (fig1/fig5/tab1/rt/ntl have dedicated runners).
pub fn figure(id: &str, quick: bool) -> Option<Figure> {
    let seeds = default_seeds(quick);
    let fig = match id {
        "fig7a" => Figure {
            id: "fig7a",
            title: "[Synthetic-Homogeneous] scaling dimensions D",
            x_name: "D",
            points: [2usize, 5, 7]
                .iter()
                .map(|&d| point(format!("D={d}"), &format!("synth:dims={d}")))
                .collect(),
            seeds,
        },
        "fig7b" => Figure {
            id: "fig7b",
            title: "[Synthetic-Homogeneous] scaling node-types m",
            x_name: "m",
            points: [5usize, 10, 15]
                .iter()
                .map(|&m| point(format!("m={m}"), &format!("synth:m={m}")))
                .collect(),
            seeds,
        },
        "fig7c" => Figure {
            id: "fig7c",
            title: "[Synthetic-Homogeneous] scaling task demand",
            x_name: "demand",
            points: [(0.01, 0.05), (0.01, 0.1), (0.01, 0.2)]
                .iter()
                .map(|&r| {
                    point(
                        format!("[{},{}]", r.0, r.1),
                        &format!("synth:dem={}..{}", r.0, r.1),
                    )
                })
                .collect(),
            seeds,
        },
        "fig8a" => Figure {
            id: "fig8a",
            title: "[GCT-2019-like, Homogeneous] scaling tasks n (m=10)",
            x_name: "n",
            points: if quick { vec![250usize, 1000] } else { vec![250, 500, 1000, 1500, 2000] }
                .into_iter()
                .map(|n| point(format!("n={n}"), &format!("gct:n={n},m=10")))
                .collect(),
            seeds,
        },
        "fig8b" => Figure {
            id: "fig8b",
            title: "[GCT-2019-like, Homogeneous] scaling node-types m (n=1000)",
            x_name: "m",
            points: [4usize, 7, 10, 13]
                .iter()
                .map(|&m| point(format!("m={m}"), &format!("gct:n=1000,m={m}")))
                .collect(),
            seeds,
        },
        "fig9" => Figure {
            id: "fig9",
            title: "[Synthetic-Heterogeneous] varying cost exponent e (D=5, m=10)",
            x_name: "e",
            points: [0.33f64, 0.5, 1.0, 2.0, 3.0]
                .iter()
                .map(|&e| point(format!("e={e}"), &format!("synth:cost=het,e={e}")))
                .collect(),
            seeds,
        },
        "fig10" => Figure {
            id: "fig10",
            title: "[GCT-2019-like, Heterogeneous] pricing-model costs, varying m (n=1000)",
            x_name: "m",
            points: [4usize, 7, 10, 13]
                .iter()
                .map(|&m| point(format!("m={m}"), &format!("gct:n=1000,m={m},priced")))
                .collect(),
            seeds,
        },
        "fig11" => Figure {
            id: "fig11",
            title: "[GCT-2019-like, All-Scenarios] PenaltyMap-F vs LP-map-F",
            x_name: "scenario",
            points: {
                let mut pts: Vec<Point> = Vec::new();
                for n in if quick { vec![250usize, 1000] } else { vec![250, 500, 1000, 1500, 2000] } {
                    pts.push(point(format!("hom n={n}"), &format!("gct:n={n},m=10")));
                }
                for m in [4usize, 7, 13] {
                    pts.push(point(format!("hom m={m}"), &format!("gct:n=1000,m={m}")));
                    pts.push(point(
                        format!("priced m={m}"),
                        &format!("gct:n=1000,m={m},priced"),
                    ));
                }
                pts
            },
            seeds,
        },
        _ => return None,
    };
    Some(fig)
}

/// The exact Figure 1 illustration instance: three time-limited tasks that
/// share one big node ($10) when the timeline is exploited, but need $16
/// of capacity if every task is treated as always-on.
pub fn figure1_instance() -> Instance {
    Instance::new(
        vec![
            Task::new(1, vec![0.60, 0.60], 0, 1),
            Task::new(2, vec![0.45, 0.30], 2, 3),
            Task::new(3, vec![0.40, 0.40], 0, 3),
        ],
        vec![
            NodeType::new("type-1", vec![1.0, 1.0], 10.0),
            NodeType::new("type-2", vec![0.5, 0.5], 6.0),
        ],
        4,
    )
}

/// Figure 2's stock-market week modeled as six tasks (one low-demand
/// long-runner + five market-hours bursts), hourly slots over one week.
pub fn figure2_tasks() -> Vec<Task> {
    let mut tasks = vec![Task::new(1, vec![0.05, 0.08], 0, 7 * 24 - 1)];
    for day in 0..5u32 {
        // market open 9:00-17:00, Monday = day 0
        let start = day * 24 + 9;
        let end = day * 24 + 16;
        tasks.push(Task::new(2 + day as u64, vec![0.30, 0.20], start, end));
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::penalty_map::{map_tasks, MappingPolicy};
    use crate::algo::placement::FitPolicy;
    use crate::algo::twophase::solve_with_mapping;
    use crate::model::trim;

    #[test]
    fn figure_ids_resolve() {
        for id in all_ids() {
            if matches!(id, "fig1" | "fig5" | "tab1" | "rt" | "ntl") {
                assert!(figure(id, false).is_none());
            } else {
                let f = figure(id, false).unwrap();
                assert!(!f.points.is_empty(), "{id}");
                assert_eq!(f.id, id);
                // every point's spec builds through the shared parser
                for p in &f.points {
                    p.workload.source().unwrap_or_else(|e| {
                        panic!("{id} point {}: {e:#}", p.label)
                    });
                }
            }
        }
    }

    #[test]
    fn quick_mode_shrinks() {
        let full = figure("fig8a", false).unwrap();
        let quick = figure("fig8a", true).unwrap();
        assert!(quick.points.len() < full.points.len());
        assert!(quick.seeds.len() < full.seeds.len());
    }

    #[test]
    fn figure1_story_holds() {
        let inst = figure1_instance();
        // timeline-aware: everything fits one type-1 node
        let tr = trim(&inst).instance;
        let sol = solve_with_mapping(&tr, &[0, 0, 0], FitPolicy::FirstFit, false);
        assert!(sol.verify(&tr).is_ok());
        assert_eq!(sol.nodes.len(), 1);
        assert!((sol.cost(&tr) - 10.0).abs() < 1e-9);

        // timeline-agnostic: the best packing needs $16
        let collapsed = inst.collapse_timeline();
        let mapping = map_tasks(&collapsed, MappingPolicy::HAvg);
        let sol = solve_with_mapping(&collapsed, &mapping, FitPolicy::FirstFit, true);
        assert!(sol.verify(&collapsed).is_ok());
        assert!(sol.cost(&collapsed) >= 16.0 - 1e-9, "got {}", sol.cost(&collapsed));
    }

    #[test]
    fn figure2_shape() {
        let tasks = figure2_tasks();
        assert_eq!(tasks.len(), 6);
        assert_eq!(tasks[0].span_len(), 7 * 24);
        for t in &tasks[1..] {
            assert_eq!(t.span_len(), 8);
        }
    }
}
