//! Figure runner: instantiates scenarios, evaluates all algorithms over
//! the seed set, and aggregates paper-style rows.

use anyhow::Result;

use crate::coordinator::planner::Planner;
use crate::io::gct_like::Trace;
use crate::io::workload::{self, WorkloadSpec};
use crate::model::Instance;
use crate::util::stats::Summary;

use super::scenarios::Figure;

/// Master GCT-like trace: ~13K tasks, 13 shapes (paper section VI-A),
/// generated once per process (cached by `io::workload`).
pub fn master_trace() -> &'static Trace {
    workload::master_trace()
}

/// Materialize the instance for a workload spec and seed, through the
/// same registry every other entry point uses.
pub fn instantiate(spec: &WorkloadSpec, seed: u64) -> Result<Instance> {
    spec.source()?.generate(seed)
}

/// Aggregated results for one figure point. Algorithm columns are
/// label-keyed and positionally aligned across `algos`, `normalized`
/// and `seconds` (the planner's portfolio order — by default the four
/// paper presets in figure-legend order).
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    /// Algorithm display labels, one per column.
    pub algos: Vec<String>,
    /// Normalized-cost summary per algorithm column.
    pub normalized: Vec<Summary>,
    pub lower_bound: Summary,
    /// Mean wall seconds per algorithm column. Sweeps race the portfolio
    /// (`Planner::evaluate`), so these are contended race wall times —
    /// not comparable to isolated sequential timings; the `rt` special
    /// runner measures those via `Planner::evaluate_sequential`. The
    /// figure JSON carries a `timing: parallel-race` marker for this.
    pub seconds: Vec<f64>,
    /// Mean wall seconds of the lower-bound extras.
    pub lb_seconds: f64,
    pub backend: &'static str,
}

impl Row {
    /// Normalized-cost summary for one algorithm by label.
    pub fn get(&self, label: &str) -> Option<&Summary> {
        self.algos.iter().position(|a| a == label).map(|i| &self.normalized[i])
    }
}

#[derive(Clone, Debug)]
pub struct FigureResult {
    pub id: String,
    pub title: String,
    pub x_name: String,
    pub rows: Vec<Row>,
}

/// Evaluate a full figure sweep.
pub fn run_figure(planner: &Planner, fig: &Figure) -> Result<FigureResult> {
    let mut rows = Vec::with_capacity(fig.points.len());
    for point in &fig.points {
        let mut algos: Vec<String> = Vec::new();
        let mut normalized: Vec<Vec<f64>> = Vec::new();
        let mut secs: Vec<f64> = Vec::new();
        let mut lbs = Vec::new();
        let mut lb_seconds = 0.0f64;
        let mut backend = "";
        for &seed in &fig.seeds {
            let inst = instantiate(&point.workload, seed)?;
            let row = planner.evaluate(&inst)?;
            if algos.is_empty() {
                algos = row.algos.iter().map(|a| a.label.clone()).collect();
                normalized = vec![Vec::new(); algos.len()];
                secs = vec![0.0; algos.len()];
            }
            anyhow::ensure!(
                row.algos.len() == algos.len(),
                "portfolio shape changed mid-sweep"
            );
            for (k, a) in row.algos.iter().enumerate() {
                normalized[k].push(a.normalized);
                secs[k] += a.seconds / fig.seeds.len() as f64;
            }
            lbs.push(row.lower_bound);
            lb_seconds += row.lb_seconds / fig.seeds.len() as f64;
            backend = row.backend_used;
        }
        eprintln!(
            "  [{}] {}: {} ({})",
            fig.id,
            point.label,
            algos
                .iter()
                .zip(&normalized)
                .map(|(a, n)| format!("{a}={:.3}", crate::util::stats::mean(n)))
                .collect::<Vec<_>>()
                .join(" "),
            backend,
        );
        rows.push(Row {
            label: point.label.clone(),
            algos,
            normalized: normalized.iter().map(|n| Summary::of(n)).collect(),
            lower_bound: Summary::of(&lbs),
            seconds: secs,
            lb_seconds,
            backend,
        });
    }
    Ok(FigureResult {
        id: fig.id.to_string(),
        title: fig.title.to_string(),
        x_name: fig.x_name.to_string(),
        rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Backend;
    use crate::harness::scenarios;

    #[test]
    fn instantiate_specs() {
        let spec = WorkloadSpec::parse("synth:n=30,m=3").unwrap();
        assert_eq!(instantiate(&spec, 1).unwrap().n_tasks(), 30);
        let g = instantiate(&WorkloadSpec::parse("gct:n=50,m=5").unwrap(), 1).unwrap();
        assert_eq!(g.n_tasks(), 50);
        // homogeneous re-pricing: cost == capacity sum
        for b in &g.node_types {
            let sum: f64 = b.capacity.iter().sum();
            assert!((b.cost - sum).abs() < 1e-12);
        }
        let gp =
            instantiate(&WorkloadSpec::parse("gct:n=50,m=5,priced").unwrap(), 1).unwrap();
        for b in &gp.node_types {
            assert!(b.cost > 0.0);
        }
        // pattern families flow through the same entry point
        let mixed =
            instantiate(&WorkloadSpec::parse("mixed:services=10,m=3").unwrap(), 2).unwrap();
        assert!(mixed.is_feasible());
        // bad specs error instead of aborting the process
        let mut bad = WorkloadSpec::parse("synth").unwrap();
        bad.set("n", "zero");
        assert!(instantiate(&bad, 1).is_err());
    }

    #[test]
    fn tiny_figure_sweep() {
        // shrunken fig7a-style sweep exercises the whole runner
        let planner = Planner::new(Backend::Native).unwrap();
        let mut fig = scenarios::figure("fig7a", true).unwrap();
        fig.seeds = vec![1];
        for p in fig.points.iter_mut() {
            p.workload.set("n", "60");
            p.workload.set("m", "4");
        }
        fig.points.truncate(2);
        let res = run_figure(&planner, &fig).unwrap();
        assert_eq!(res.rows.len(), 2);
        for row in &res.rows {
            for s in &row.normalized {
                assert!(s.mean >= 1.0 - 1e-6, "normalized {:?}", s);
            }
        }
    }
}
