//! Figure runner: instantiates scenarios, evaluates all algorithms over
//! the seed set, and aggregates paper-style rows.

use std::sync::OnceLock;

use anyhow::Result;

use crate::coordinator::config::TraceKind;
use crate::coordinator::planner::Planner;
use crate::io::gct_like::{self, Trace};
use crate::io::synth;
use crate::model::{CostModel, Instance};
use crate::util::stats::Summary;

use super::scenarios::Figure;

/// Master GCT-like trace: ~13K tasks, 13 shapes (paper section VI-A),
/// generated once per process.
pub fn master_trace() -> &'static Trace {
    static TRACE: OnceLock<Trace> = OnceLock::new();
    TRACE.get_or_init(|| gct_like::generate_trace(13_000, 0x6c7_2019))
}

/// Materialize the instance for a trace kind and seed.
pub fn instantiate(trace: &TraceKind, seed: u64) -> Instance {
    match trace {
        TraceKind::Synthetic(params) => synth::generate(params, seed),
        TraceKind::GctLike { n, m, priced } => {
            let mut inst = master_trace().sample_scenario(*n, *m, seed);
            if !priced {
                // homogeneous-linear experiments re-price cap-sum = cost
                CostModel::homogeneous(inst.dims()).apply(&mut inst.node_types);
            }
            inst
        }
    }
}

/// Aggregated results for one figure point. Algorithm columns are
/// label-keyed and positionally aligned across `algos`, `normalized`
/// and `seconds` (the planner's portfolio order — by default the four
/// paper presets in figure-legend order).
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    /// Algorithm display labels, one per column.
    pub algos: Vec<String>,
    /// Normalized-cost summary per algorithm column.
    pub normalized: Vec<Summary>,
    pub lower_bound: Summary,
    /// Mean wall seconds per algorithm column. Sweeps race the portfolio
    /// (`Planner::evaluate`), so these are contended race wall times —
    /// not comparable to isolated sequential timings; the `rt` special
    /// runner measures those via `Planner::evaluate_sequential`. The
    /// figure JSON carries a `timing: parallel-race` marker for this.
    pub seconds: Vec<f64>,
    /// Mean wall seconds of the lower-bound extras.
    pub lb_seconds: f64,
    pub backend: &'static str,
}

impl Row {
    /// Normalized-cost summary for one algorithm by label.
    pub fn get(&self, label: &str) -> Option<&Summary> {
        self.algos.iter().position(|a| a == label).map(|i| &self.normalized[i])
    }
}

#[derive(Clone, Debug)]
pub struct FigureResult {
    pub id: String,
    pub title: String,
    pub x_name: String,
    pub rows: Vec<Row>,
}

/// Evaluate a full figure sweep.
pub fn run_figure(planner: &Planner, fig: &Figure) -> Result<FigureResult> {
    let mut rows = Vec::with_capacity(fig.points.len());
    for point in &fig.points {
        let mut algos: Vec<String> = Vec::new();
        let mut normalized: Vec<Vec<f64>> = Vec::new();
        let mut secs: Vec<f64> = Vec::new();
        let mut lbs = Vec::new();
        let mut lb_seconds = 0.0f64;
        let mut backend = "";
        for &seed in &fig.seeds {
            let inst = instantiate(&point.trace, seed);
            let row = planner.evaluate(&inst)?;
            if algos.is_empty() {
                algos = row.algos.iter().map(|a| a.label.clone()).collect();
                normalized = vec![Vec::new(); algos.len()];
                secs = vec![0.0; algos.len()];
            }
            anyhow::ensure!(
                row.algos.len() == algos.len(),
                "portfolio shape changed mid-sweep"
            );
            for (k, a) in row.algos.iter().enumerate() {
                normalized[k].push(a.normalized);
                secs[k] += a.seconds / fig.seeds.len() as f64;
            }
            lbs.push(row.lower_bound);
            lb_seconds += row.lb_seconds / fig.seeds.len() as f64;
            backend = row.backend_used;
        }
        eprintln!(
            "  [{}] {}: {} ({})",
            fig.id,
            point.label,
            algos
                .iter()
                .zip(&normalized)
                .map(|(a, n)| format!("{a}={:.3}", crate::util::stats::mean(n)))
                .collect::<Vec<_>>()
                .join(" "),
            backend,
        );
        rows.push(Row {
            label: point.label.clone(),
            algos,
            normalized: normalized.iter().map(|n| Summary::of(n)).collect(),
            lower_bound: Summary::of(&lbs),
            seconds: secs,
            lb_seconds,
            backend,
        });
    }
    Ok(FigureResult {
        id: fig.id.to_string(),
        title: fig.title.to_string(),
        x_name: fig.x_name.to_string(),
        rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Backend;
    use crate::harness::scenarios;

    #[test]
    fn instantiate_both_kinds() {
        let s = instantiate(
            &TraceKind::Synthetic(synth::SynthParams { n: 30, m: 3, ..Default::default() }),
            1,
        );
        assert_eq!(s.n_tasks(), 30);
        let g = instantiate(&TraceKind::GctLike { n: 50, m: 5, priced: false }, 1);
        assert_eq!(g.n_tasks(), 50);
        // homogeneous re-pricing: cost == capacity sum
        for b in &g.node_types {
            let sum: f64 = b.capacity.iter().sum();
            assert!((b.cost - sum).abs() < 1e-12);
        }
        let gp = instantiate(&TraceKind::GctLike { n: 50, m: 5, priced: true }, 1);
        for b in &gp.node_types {
            assert!(b.cost > 0.0);
        }
    }

    #[test]
    fn tiny_figure_sweep() {
        // shrunken fig7a-style sweep exercises the whole runner
        let planner = Planner::new(Backend::Native).unwrap();
        let mut fig = scenarios::figure("fig7a", true).unwrap();
        fig.seeds = vec![1];
        for p in fig.points.iter_mut() {
            if let TraceKind::Synthetic(sp) = &mut p.trace {
                sp.n = 60;
                sp.m = 4;
            }
        }
        fig.points.truncate(2);
        let res = run_figure(&planner, &fig).unwrap();
        assert_eq!(res.rows.len(), 2);
        for row in &res.rows {
            for s in &row.normalized {
                assert!(s.mean >= 1.0 - 1e-6, "normalized {:?}", s);
            }
        }
    }
}
