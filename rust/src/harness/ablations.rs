//! Ablation studies for the design choices DESIGN.md calls out:
//!   A1 omega adaptation on/off (PDHG primal weight),
//!   A2 crossover on/off (near-vertex pull before rounding),
//!   A3 cross-fill node-type ordering (capacity/cost vs index order),
//!   A4 small/large segregation on/off,
//!   A5 local search post-pass on/off,
//!   A6 offline vs online placement.
//!
//! Run via `tlrs ablations [--quick]`; each row reports cost normalized by
//! the certified lower bound, averaged over seeds.

use anyhow::Result;

use crate::algo::online;
use crate::algo::penalty_map::{map_tasks, MappingPolicy};
use crate::algo::pipeline::{self, CrossFill, LocalSearch, Oracle, Pipeline};
use crate::algo::placement::FitPolicy;
use crate::algo::segregate;
use crate::algo::twophase::solve_with_mapping;
use crate::io::workload::WorkloadSpec;
use crate::lp::pdhg::{self, PdhgOptions};
use crate::lp::solver::NativePdhgSolver;
use crate::lp::{scaling, MappingLp};
use crate::model::trim;
use crate::util::stats;

use super::runner::instantiate;

pub fn run(quick: bool) -> Result<String> {
    let seeds: Vec<u64> = if quick { vec![1, 2] } else { vec![1, 2, 3, 4, 5] };
    let mut out = String::from("== ablations (normalized cost / iterations) ==\n");

    // workloads: synthetic default + GCT-like, as specs through the
    // shared workload parser
    let n = if quick { 300 } else { 1000 };
    let traces = [
        ("synth", WorkloadSpec::parse(&format!("synth:n={n}"))?),
        ("gct", WorkloadSpec::parse(&format!("gct:n={n},m=10"))?),
    ];

    for (tname, trace) in &traces {
        let mut lp_iters_adapt = Vec::new();
        let mut lp_iters_plain = Vec::new();
        let mut norm = vec![Vec::new(); 7]; // variants below
        for &seed in &seeds {
            let inst = instantiate(trace, seed)?;
            let tr = trim(&inst).instance;
            let solver = NativePdhgSolver::default();

            // reference: the LP-map-F preset + the A5 combo pipeline,
            // raced on one shared LP solve; LB from the certified dual
            let race = pipeline::Portfolio::new()
                .add(pipeline::preset("lp-map-f").unwrap())
                .add(
                    Pipeline::new()
                        .map(pipeline::Lp)
                        .refine(CrossFill)
                        .refine(LocalSearch::default())
                        .label("lp+fill+ls"),
                )
                .run(&tr, &solver)?;
            let rep = &race.reports[0];
            let a5 = &race.reports[1];
            let lb = rep.certified_lb.expect("LP pipelines certify a bound");
            anyhow::ensure!(lb > 0.0);

            // A1: omega adaptation (solver-level; measure iterations)
            let mut lp = MappingLp::from_instance(&tr);
            scaling::equilibrate(&mut lp);
            let plain = pdhg::solve(&lp, &PdhgOptions::default());
            let adapt = pdhg::solve(
                &lp,
                &PdhgOptions { adapt_omega: true, ..Default::default() },
            );
            lp_iters_plain.push(plain.iterations as f64);
            lp_iters_adapt.push(adapt.iterations as f64);

            // A2: rounding without alternates/crossover — the raw argmax
            // mapping fed back through the Oracle escape hatch
            let raw = {
                use crate::algo::lpmap::round_mapping;
                let sol = solver_solution(&lp, &solver)?;
                let (mapping, _) = round_mapping(&tr, &sol);
                Pipeline::new()
                    .map(Oracle::new("raw-argmax", mapping))
                    .fit(FitPolicy::FirstFit)
                    .refine(CrossFill)
                    .run(&tr, &solver)?
            };

            // variants: [lp-map-f, raw-rounding, penalty-f, seg, local, online, pen]
            norm[0].push(rep.cost / lb);
            norm[1].push(raw.cost / lb);
            let pen_f = pipeline::preset("penalty-map-f").unwrap().run(&tr, &solver)?;
            norm[2].push(pen_f.cost / lb);
            let seg = segregate::solve_segregated(&tr, |i| {
                let mapping = map_tasks(i, MappingPolicy::HAvg);
                solve_with_mapping(i, &mapping, FitPolicy::FirstFit, true)
            });
            norm[3].push(seg.cost(&tr) / lb);
            // A5: the previously-unreachable combo (local search refines
            // every fill candidate), evaluated on the shared LP outcome
            norm[4].push(a5.cost / lb);
            norm[5].push(online::solve_online(&tr, FitPolicy::FirstFit)?.cost(&tr) / lb);
            let pen = pipeline::preset("penalty-map").unwrap().run(&tr, &solver)?;
            norm[6].push(pen.cost / lb);
        }
        out.push_str(&format!("\n[{tname}]\n"));
        out.push_str(&format!(
            "  A1 pdhg iterations       : plain {:>9.0}  adapt-omega {:>9.0}\n",
            stats::mean(&lp_iters_plain),
            stats::mean(&lp_iters_adapt)
        ));
        let row = |label: &str, xs: &[f64]| {
            format!("  {label:<25}: {:.3} ± {:.3}\n", stats::mean(xs), stats::stddev(xs))
        };
        out.push_str(&row("LP-map-F (full)", &norm[0]));
        out.push_str(&row("A2 raw argmax rounding", &norm[1]));
        out.push_str(&row("PenaltyMap-F", &norm[2]));
        out.push_str(&row("A4 segregated PenaltyMapF", &norm[3]));
        out.push_str(&row("A5 lp+fill+ls pipeline", &norm[4]));
        out.push_str(&row("A6 online first-fit", &norm[5]));
        out.push_str(&row("PenaltyMap (no fill)", &norm[6]));
    }
    Ok(out)
}

/// Solve the LP and return the raw fractional x (helper for A2).
fn solver_solution(
    lp: &MappingLp,
    solver: &NativePdhgSolver,
) -> Result<Vec<f64>> {
    use crate::lp::solver::MappingSolver;
    Ok(solver.solve_mapping(lp)?.x)
}

#[cfg(test)]
mod tests {
    #[test]
    fn ablations_run_quick() {
        let out = super::run(true).unwrap();
        assert!(out.contains("A1"));
        assert!(out.contains("LP-map-F (full)"));
        assert!(out.contains("[gct]"));
    }
}
