//! Experiment harness: regenerates every table and figure of the paper.

pub mod ablations;
pub mod report;
pub mod runner;
pub mod scenarios;
pub mod special;
