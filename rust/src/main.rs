//! tlrs — TL-Rightsizing CLI (the L3 leader entrypoint).
//!
//! Subcommands:
//!   solve     (--input inst.json | --workload <spec>) [--algo lp-map-f]
//!             [--backend auto] [--replay]
//!   session   (--input inst.json | --workload <spec>) --deltas deltas.jsonl
//!             open a plan session and replay a delta stream incrementally
//!   gen       --workload <spec> [--seed S] --out inst.json [--csv trace.csv]
//!   workloads list the registered workload families (--names | --smoke)
//!   stress    --workload <spec> [--surprise <spec>] plan + surprise-load sim
//!   lb        --input inst.json [--backend auto]
//!   figures   <id|all> [--quick] [--backend auto] [--out-dir bench_results]
//!   serve     [--addr 127.0.0.1:7077] [--backend auto] [--workers N]
//!             [--queue K] [--request-timeout S] [--max-request-bytes B]
//!             [--allow-shutdown]
//!   info      print artifact manifest and PJRT platform
//!   help

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use tlrs::algo::pipeline;
use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::coordinator::service;
use tlrs::harness::{report, runner, scenarios, special};
use tlrs::io::files;
use tlrs::io::workload;
use tlrs::model::trim;
use tlrs::sim::autoscale;
use tlrs::sim::replay::replay;
use tlrs::util::cli::Args;
use tlrs::util::json::Json;

const USAGE: &str = "\
tlrs — cold-start cluster rightsizing for time-limited tasks (CLOUD'21)

USAGE:
  tlrs solve   (--input inst.json | --workload <wspec> [--seed 1])
               [--algo <spec>[,<spec>...]] [--decompose <dspec>]
               [--backend auto|native|artifact|simplex] [--lp-threads N]
               [--replay] [--out sol.json]
  tlrs session (--input inst.json | --workload <wspec> [--seed 1])
               --deltas deltas.jsonl [--algo <spec>] [--escalate 1.5|off]
               [--fit ff|sim] [--lp-threads N] [--check]
  tlrs gen     --workload <wspec> [--seed 1] --out inst.json [--csv trace.csv]
               (legacy: --kind synth|gct [--n ...] [--m ...] [--dims ...]
                [--horizon ...] [--priced])
  tlrs workloads [--names | --smoke]   list the registered workload families
  tlrs stress  --workload <wspec> [--surprise <wspec>] [--seed 1]
               [--algo <spec>] [--backend ...]
  tlrs lb      --input inst.json [--backend ...]
  tlrs figures <fig1|fig5|fig7a|fig7b|fig7c|fig8a|fig8b|fig9|fig10|fig11|tab1|rt|ntl|all>
               [--quick] [--backend ...] [--out-dir bench_results]
  tlrs ablations [--quick]
  tlrs serve   [--addr 127.0.0.1:7077] [--backend ...] [--lp-threads N]
               [--workers N] [--queue K] [--request-timeout <seconds>]
               [--max-request-bytes B] [--allow-shutdown]
  tlrs info

WORKLOAD SPECS (--workload, gen/solve/stress, and the service's 'workload' field):
  workload := <family>[:<key>=<value>[,<key>=<value>|<flag>]...]
  families := synth | gct | mixed | burst | batch | deadline | duty
            | spiky | waves | csv           (run 'tlrs workloads' for the
                                              full key catalog)
  shape    := flat | ramp | diurnal | spike  — every family accepts
              shape=<...>: tasks get piecewise-constant demand profiles
              (time-varying load within one task) whose peak equals the
              family's drawn demand; 'flat' (default) is the constant-
              demand model, bit-identical to omitting the key
  cost     := hom | het | gcp | fixed with e=<exponent>; composes onto
              every generated family (gct prices via its 'priced' flag)
  csv      := csv:path=<trace.csv> imports an on-disk trace (io::files
              format, '+'-prefixed continuation rows carry extra demand
              segments) and draws a priced catalog around it. CLI-only:
              the service rejects it (server-local file reads)
  examples : --workload synth:n=2000,dims=7    --workload gct:n=1000,priced
             --workload mixed:services=200,shape=diurnal
             --workload csv:path=trace.csv,m=6,cost=gcp

ALGO SPECS (--algo, and the service's 'algorithm' field):
  A preset, a pipeline spec, or several specs separated by commas —
  multiple specs race in parallel as a portfolio sharing one LP solve,
  and the min-cost solution wins; racers that a finished member's
  certified LP bound proves unbeatable are skipped (reported as such).
  The spec token 'portfolio' expands to all four presets and may appear
  inside comma lists.
  spec    := portfolio | <head>[:<fit>][+<refine>]...
  head    := penalty-map | penalty-map-f | lp-map | lp-map-f
           | penalty | penalty-havg | penalty-hmax | lp
  fit     := ff | sim | best            (default: best = race both)
  refine  := fill | ls[:<max_rounds>]   (fill must be the first refine)
  examples: --algo lp+fill+ls    --algo penalty:ff+ls:16
            --algo portfolio     --algo lp-map-f+ls,portfolio

LP THREADS (--lp-threads, and the service's 'lp_threads' field):
  Worker threads for the native PDHG LP kernels (operator applies,
  proximal steps, reductions) and the LP build. 0 (the default) auto-
  sizes to half the cores, capped at 8, leaving headroom for the
  portfolio race and decomposed-partition workers; explicit counts are
  capped at 64. Results are bit-identical for every value — parallel
  runs reproduce the serial solve to the last bit (fixed-boundary
  blocks, fixed-order combines; see lp::pdhg). Decomposed solves split
  the budget across concurrent partitions. Over the service, requests
  may carry \"lp_threads\": N per solve/open (values past the cap are
  request errors); the resolved count is echoed in the response and in
  the 'lp_threads_used' stats gauge.

DECOMPOSED SOLVES (--decompose, and the service's 'decompose' field):
  Partition the tasks, solve every partition concurrently through the
  same --algo portfolio, merge, and stitch (a cross-fill pass that
  drains under-utilized nodes across partition seams — never raises
  cost). Built for very large instances: each partition's mapping LP is
  a fraction of the monolith's, and partitions race on separate
  workers.
  dspec   := window[:k] | dims[:k] | size[:k]        (k <= 64)
  window  := sort by start time, k near-equal chunks (default k=8).
             Best when load is spread over a long horizon.
  dims    := group tasks by their dominant resource dimension (argmax
             demand/mean-capacity); k keeps the k-1 largest groups and
             merges the rest. Best for multi-resource mixes (CPU-heavy
             vs memory-heavy pools).
  size    := the small/large split of the paper's segregation pass;
             smalls are chunked into k-1 parts (default k=2). Best when
             a few whale tasks dominate.
  The reported lower bound stays certified: max over partitions of the
  partition's certified LB (restricting any global solution to a
  partition's tasks stays feasible), floored by the whole-instance
  congestion bound. The per-partition-sum bound is also reported — it
  certifies the pre-stitch decomposition, not the global optimum.
  k=1 is bit-identical to the non-decomposed sequential portfolio.
  examples: --decompose window:16   --decompose dims
            --decompose size:4 --algo penalty-map,penalty-map-f

PLAN SESSIONS (tlrs session, and the service's 'op' verbs):
  A session opens a plan once (full solve via --algo) and then answers a
  stream of workload deltas incrementally: untouched placements are kept
  and only affected nodes are repaired, escalating to a full re-solve
  (PDHG warm-started from the retained iterates) only when the
  incremental cost drifts past --escalate x the refreshed certified LB
  ('off' never escalates). Every delta's plan is per-slot verified.
  --deltas is JSON-lines, one delta per line ('#' comments allowed):
    {\"op\": \"admit\",   \"tasks\": [{\"id\",\"demand\",\"start\",\"end\"} | segments...]}
    {\"op\": \"retire\",  \"ids\": [3, 17]}
    {\"op\": \"reshape\", \"id\": 3, \"demand\": [...], \"start\": s, \"end\": e}
    {\"op\": \"reshape\", \"id\": 3, \"segments\": [{start,end,demand}...]}
    {\"op\": \"reprice\", \"node_types\": [{name,capacity,cost}...]}
  --check asserts per-delta invariants (cost >= certified LB) and exits
  non-zero on violation. The service speaks the same layer over TCP:
  {\"op\": \"open\"|\"delta\"|\"query\"|\"close\"|\"stats\"|\"shutdown\"} — 'query'
  prices a delta without committing it, 'stats' dumps counters, gauges
  and latency histograms. See coordinator::service docs.

SERVICE RUNTIME (tlrs serve):
  Line-delimited JSON over TCP on a concurrent accept/worker runtime:
  an accept thread feeds --workers N connection workers (default: CPU
  count) with a bounded queue of --queue K waiting connections (default
  2xN). Each connection occupies one worker for its lifetime and may
  pipeline many request lines. At --workers 1 --queue 0 the service is
  strictly sequential and responses are byte-identical to handling the
  requests directly.
  Admission : past N active + K queued connections, new ones are shed
              with one line {\"ok\":false,\"error\":\"overloaded\",
              \"retry_after_ms\":...} and closed — back off and retry.
  Budgets   : a request line longer than --max-request-bytes (default
              64 MiB) answers {\"ok\":false,\"error\":\"request too large\",
              ...} and closes the connection (no way to resync inside a
              line). A request that runs past --request-timeout (default
              120s) answers {\"ok\":false,\"error\":\"timeout\",...} instead
              of its result; the side effect still happened (a late
              session delta stays applied — query the session to
              resync).
  Shutdown  : {\"op\":\"shutdown\"} (only with --allow-shutdown) stops the
              accept loop, drains every in-flight and queued request,
              closes all sessions, and exits 0. Without the flag the verb
              is refused and the server keeps running.
  Stats     : {\"op\":\"stats\"} adds gauges (live/peak connections, queue
              depth) and per-verb latency histograms (request.solve,
              request.delta, ...) next to the existing counters/timers.
  The PJRT artifact backend is single-client; serve moves it onto a
  dedicated solver thread at startup so any --workers count is safe
  (artifact-routed solves still serialize; native solves run
  concurrently).
  Wire layer: hot request shapes (inline instances, delta payloads)
              pull-parse straight into typed structs and responses are
              direct-written — no JSON tree in between. Anything else
              falls back to the DOM path with identical responses and
              error text, so clients never see the difference (see
              util::wire).
";

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn planner_from(args: &Args) -> Result<Planner> {
    let backend = Backend::parse(&args.get_or("backend", "auto"))?;
    let mut planner = Planner::new(backend)?;
    planner.set_lp_threads(args.get_usize("lp-threads", 0));
    Ok(planner)
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "solve" => cmd_solve(args),
        "session" => cmd_session(args),
        "gen" => cmd_gen(args),
        "workloads" => cmd_workloads(args),
        "stress" => cmd_stress(args),
        "lb" => cmd_lb(args),
        "figures" => cmd_figures(args),
        "ablations" => {
            let out = tlrs::harness::ablations::run(args.has_flag("quick"))?;
            print!("{out}");
            Ok(())
        }
        "serve" => cmd_serve(args),
        "info" => cmd_info(),
        "" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

/// Load the instance a command operates on: an on-disk file (`--input`)
/// or a generated workload (`--workload <spec>` + `--seed`).
fn instance_from(args: &Args) -> Result<tlrs::model::Instance> {
    match (args.get("input"), args.get("workload")) {
        (Some(path), None) => files::load_instance(Path::new(path)),
        (None, Some(spec)) => {
            workload::parse_workload(spec)?.generate(args.get_u64("seed", 1))
        }
        _ => bail!("exactly one of --input or --workload is required"),
    }
}

fn cmd_solve(args: &Args) -> Result<()> {
    let inst = instance_from(args)?;
    let planner = planner_from(args)?;
    let algo = args.get_or("algo", "lp-map-f");

    let tr = trim(&inst).instance;

    // --algo: one spec runs a single pipeline; 'portfolio' and/or a
    // comma-separated list races the specs in parallel on one LP solve
    // (the service accepts the identical language).
    let portfolio = pipeline::parse_portfolio(&algo)?;

    // --decompose: partition the tasks and solve the parts concurrently
    // through the same portfolio (see the DECOMPOSED SOLVES section).
    if let Some(dspec) = args.get("decompose") {
        let spec = tlrs::algo::decompose::parse_decompose(dspec)?;
        return cmd_solve_decomposed(args, &planner, &tr, &portfolio, &spec);
    }

    let (solver, backend) = planner.solver_for(&tr);

    let t0 = std::time::Instant::now();
    let race = portfolio.run(&tr, solver.as_ref())?;
    let dt = t0.elapsed();
    let report = race.best();
    let solution = &report.solution;
    let lb = race.certified_lb();
    solution
        .verify(&tr)
        .map_err(|v| anyhow::anyhow!("infeasible solution produced: {v:?}"))?;

    let cost = report.cost;
    println!("algorithm      : {} ({backend})", report.label);
    if race.reports.len() + race.skipped.len() > 1 {
        for (i, r) in race.reports.iter().enumerate() {
            let marker = if i == race.winner { " <- winner" } else { "" };
            println!("  raced        : {:<24} cost {:.4}{marker}", r.label, r.cost);
        }
        for label in &race.skipped {
            println!("  raced        : {label:<24} skipped (LP bound reached)");
        }
    }
    println!("tasks / types  : {} / {}", tr.n_tasks(), tr.n_types());
    println!("trimmed T      : {}", tr.horizon);
    println!("nodes purchased: {}", solution.nodes.len());
    println!("cluster cost   : {cost:.4}");
    if let Some(lb) = lb {
        println!("lower bound    : {lb:.4}  (normalized cost {:.3})", cost / lb);
    }
    if race.lp_seconds > 0.0 {
        println!(
            "lp solve       : {:.3}s (shared across {} pipeline(s))",
            race.lp_seconds,
            race.reports.len()
        );
    }
    println!("stage times    : {}", report.stage_summary());
    println!("solve time     : {dt:?}");
    if args.has_flag("replay") {
        let rep = replay(&tr, &solution);
        println!(
            "replay         : {} overloads, avg utilization {:.1}%, peak tasks {}",
            rep.overloads,
            rep.avg_utilization * 100.0,
            rep.peak_tasks
        );
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, files::solution_to_json(&solution, &tr).to_string())?;
        println!("solution       : wrote {out}");
    }
    Ok(())
}

/// The `--decompose` arm of `tlrs solve`: partitioned concurrent solve
/// with the partition table, the two-tier bound report, and stitch
/// telemetry.
fn cmd_solve_decomposed(
    args: &Args,
    planner: &Planner,
    tr: &tlrs::model::Instance,
    portfolio: &pipeline::Portfolio,
    spec: &tlrs::algo::decompose::DecomposeSpec,
) -> Result<()> {
    let t0 = std::time::Instant::now();
    let (rep, backend) = planner.solve_decomposed(tr, portfolio, spec)?;
    let dt = t0.elapsed();
    rep.solution
        .verify(tr)
        .map_err(|v| anyhow::anyhow!("infeasible decomposed solution: {v:?}"))?;

    println!("decompose      : {spec} -> {} partition(s) ({backend})", rep.partitions.len());
    for p in &rep.partitions {
        println!(
            "  partition    : {:<14} {:>7} tasks  cost {:>10.4}  lb {:>10.4}  \
             {:.3}s  ({})",
            p.label, p.n_tasks, p.cost, p.lb, p.seconds, p.winner
        );
    }
    println!("tasks / types  : {} / {}", tr.n_tasks(), tr.n_types());
    println!("trimmed T      : {}", tr.horizon);
    println!("nodes purchased: {}", rep.solution.nodes.len());
    println!("cluster cost   : {:.4}", rep.cost);
    if rep.pre_stitch_cost > rep.cost + 1e-12 {
        println!(
            "stitch         : {:.4} -> {:.4} ({:.2}% saved in {:.3}s)",
            rep.pre_stitch_cost,
            rep.cost,
            100.0 * (rep.pre_stitch_cost - rep.cost) / rep.pre_stitch_cost,
            rep.stitch_seconds
        );
    } else {
        println!("stitch         : no cross-partition savings ({:.3}s)", rep.stitch_seconds);
    }
    println!(
        "lower bound    : {:.4}  (normalized cost {:.3})",
        rep.certified_lb,
        rep.cost / rep.certified_lb.max(1e-12)
    );
    println!(
        "  sum of parts : {:.4} (decomposition certificate), congestion {:.4}",
        rep.sum_lb, rep.congestion_lb
    );
    let stage_summary = rep
        .stages
        .iter()
        .map(|s| format!("{} {:.3}s", s.stage, s.seconds))
        .collect::<Vec<_>>()
        .join(", ");
    println!("stage times    : {stage_summary}");
    println!("solve time     : {dt:?}");
    if args.has_flag("replay") {
        let r = replay(tr, &rep.solution);
        println!(
            "replay         : {} overloads, avg utilization {:.1}%, peak tasks {}",
            r.overloads,
            r.avg_utilization * 100.0,
            r.peak_tasks
        );
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, files::solution_to_json(&rep.solution, tr).to_string())?;
        println!("solution       : wrote {out}");
    }
    Ok(())
}

/// Open a plan session and replay a JSON-lines delta stream through the
/// incremental re-solve path, printing one line per delta (repair vs
/// full-re-solve decision, cost, refreshed certified LB).
fn cmd_session(args: &Args) -> Result<()> {
    use tlrs::coordinator::session::{self, PlanSession, SessionConfig};
    use tlrs::io::delta::load_delta_stream;

    let inst = instance_from(args)?;
    let deltas_path = args.get("deltas").context(
        "--deltas <file.jsonl> required (one delta object per line; see USAGE)",
    )?;
    let deltas = load_delta_stream(Path::new(deltas_path))?;
    let check = args.has_flag("check");

    let cfg = SessionConfig {
        algo: args.get_or("algo", "lp-map-f"),
        fit: session::parse_fit(&args.get_or("fit", "ff"))?,
        escalate_ratio: session::parse_escalate(&args.get_or("escalate", "1.5"))?,
        warm: true,
        lp_threads: args.get_usize("lp-threads", 0),
    };
    let escalate_desc = match cfg.escalate_ratio {
        Some(r) => format!("{r:.2} x LB"),
        None => "off".into(),
    };
    let (mut session, open) = PlanSession::open(inst, cfg)?;
    println!(
        "open           : {} tasks, cost {:.4}, LB {:.4}, {} nodes ({} in {:.3}s, \
         escalate {})",
        open.n_tasks, open.cost, open.lower_bound, open.n_nodes, open.label,
        open.seconds, escalate_desc
    );

    let mut violations = 0usize;
    for (i, delta) in deltas.iter().enumerate() {
        let rep = session
            .apply(delta)
            .with_context(|| format!("delta {} ({})", i + 1, delta.op()))?;
        let ratio = if rep.lower_bound > 0.0 { rep.cost / rep.lower_bound } else { 1.0 };
        println!(
            "#{:<4} {:<8} {:<8} cost {:>10.4}  lb {:>10.4}  x{:<6.3} nodes {:<5} \
             tasks {:<6} {:.3}s{}",
            i + 1,
            rep.op,
            rep.decision.as_str(),
            rep.cost,
            rep.lower_bound,
            ratio,
            rep.n_nodes,
            rep.n_tasks,
            rep.seconds,
            rep.reason.as_deref().map(|r| format!("  ({r})")).unwrap_or_default()
        );
        if check && rep.cost < rep.lower_bound - 1e-6 {
            eprintln!("CHECK FAILED: cost {} below certified LB {}", rep.cost, rep.lower_bound);
            violations += 1;
        }
    }
    let (n, repairs, resolves) = session.delta_counts();
    println!(
        "session        : {n} deltas ({repairs} incremental repairs, {resolves} full \
         re-solves), final cost {:.4}, LB {:.4}, {} nodes",
        session.cost(),
        session.lower_bound(),
        session.n_nodes()
    );
    if check {
        // every intermediate state was already per-slot verified by the
        // session; re-verify the final state with the independent dense
        // backend as a belt-and-suspenders gate
        session
            .solution()
            .verify_with::<tlrs::model::DenseProfile>(session.instance())
            .map_err(|v| anyhow::anyhow!("final state fails dense verify: {v:?}"))?;
        anyhow::ensure!(violations == 0, "{violations} check violation(s)");
        println!("session check  : OK (all deltas verify-clean, cost >= certified LB)");
    }
    Ok(())
}

/// Translate the legacy `--kind synth|gct` flags into a [`WorkloadSpec`]
/// built with the shared grammar machinery, forwarding only the keys
/// each kind historically understood (a gct `--dims` or a synth
/// `--priced` was silently ignored before the registry existed, and
/// still is — old scripts keep working).
fn legacy_gen_spec(args: &Args) -> Result<workload::WorkloadSpec> {
    let kind = args.get_or("kind", "synth");
    let keys: &[&str] = match kind.as_str() {
        "synth" => &["n", "m", "dims", "horizon"],
        "gct" => &["n", "m"],
        other => bail!(
            "unknown --kind '{other}' (use --workload <spec>; run 'tlrs workloads' \
             for the family catalog)"
        ),
    };
    let mut spec = workload::WorkloadSpec::parse(&kind)?;
    for key in keys {
        if let Some(v) = args.get(key) {
            spec.set(key, v);
        }
    }
    if kind == "gct" && args.has_flag("priced") {
        spec.set("priced", "");
    }
    Ok(spec)
}

fn cmd_gen(args: &Args) -> Result<()> {
    let out = args.get("out").context("--out required")?;
    let seed = args.get_u64("seed", 1);
    let source = match args.get("workload") {
        Some(w) => {
            // mixing the forms would silently ignore the legacy flags
            let legacy_given = ["kind", "n", "m", "dims", "horizon"]
                .iter()
                .any(|k| args.get(k).is_some())
                || args.has_flag("priced");
            anyhow::ensure!(
                !legacy_given,
                "--workload carries its own parameters; do not combine it with \
                 the legacy --kind/--n/--m/--dims/--horizon/--priced flags"
            );
            workload::parse_workload(w)?
        }
        None => legacy_gen_spec(args)?.source()?,
    };
    let inst = source.generate(seed)?;
    files::save_instance(&inst, Path::new(out))?;
    println!(
        "wrote {} ({} tasks, {} node-types) from '{}' seed {}",
        out,
        inst.n_tasks(),
        inst.n_types(),
        source.label(),
        seed
    );
    if let Some(csv) = args.get("csv") {
        files::save_trace_csv(&inst.tasks, Path::new(csv))?;
        println!("wrote {csv}");
    }
    Ok(())
}

/// List the registered workload families: full catalog by default,
/// `--names` for scripting, `--smoke` for the tier-1 generator smoke loop.
fn cmd_workloads(args: &Args) -> Result<()> {
    for fam in workload::families() {
        if args.has_flag("names") {
            println!("{}", fam.name);
        } else if args.has_flag("smoke") {
            println!("{}", fam.smoke_spec);
        } else {
            println!("{:<9} {}", fam.name, fam.summary);
            for (key, help) in fam.keys {
                println!("    {key:<9} {help}");
            }
        }
    }
    if !args.has_flag("names") && !args.has_flag("smoke") {
        println!("\nspec grammar:\n{}", workload::WORKLOAD_GRAMMAR);
    }
    Ok(())
}

/// Plan a workload, then stress the plan with surprise load through the
/// admission/auto-scaling simulator (the paper's future-work hook).
fn cmd_stress(args: &Args) -> Result<()> {
    let spec = args.get("workload").context("--workload required")?;
    let source = workload::parse_workload(spec)?;
    let seed = args.get_u64("seed", 1);
    let inst = source.generate(seed)?;
    // the plan lives on the trimmed (rank-compacted) timeline, so the
    // surprise load must be generated on that horizon too — otherwise
    // every late arrival would clip onto the final trimmed slot
    let tr = trim(&inst).instance;
    // default surprise: a spiky burst of ~25% extra services
    let surprise = match args.get("surprise") {
        Some(s) => {
            let mut spec = workload::WorkloadSpec::parse(s)?;
            // align the surprise timeline with the plan unless the spec
            // pins its own horizon (families without one, e.g. gct, are
            // left as-is and rejected by sim::autoscale::stress if long)
            if spec.get("horizon").is_none()
                && spec.family_info()?.keys.iter().any(|(k, _)| *k == "horizon")
            {
                spec.set("horizon", tr.horizon.to_string());
            }
            spec.source()?
        }
        None => workload::parse_workload(&format!(
            "spiky:services={},dims={},horizon={}",
            (tr.n_tasks() / 4).max(1),
            tr.dims(),
            tr.horizon
        ))?,
    };

    let planner = planner_from(args)?;
    let (solver, backend) = planner.solver_for(&tr);
    let portfolio = pipeline::parse_portfolio(&args.get_or("algo", "lp-map-f"))?;
    let race = portfolio.run(&tr, solver.as_ref())?;
    let plan = &race.best().solution;
    plan.verify(&tr)
        .map_err(|v| anyhow::anyhow!("infeasible plan produced: {v:?}"))?;

    let out = autoscale::stress(
        &tr,
        plan,
        surprise.as_ref(),
        seed ^ 0x5712e55,
        tlrs::algo::placement::FitPolicy::FirstFit,
    )?;
    println!("workload       : {} ({})", source.label(), source.describe());
    println!("plan           : {} on {backend}, cost {:.4}", race.best().label, race.best().cost);
    println!("surprise       : {} ({} tasks)", out.surprise, out.surprise_tasks);
    println!(
        "planned load   : {:.1}% admitted",
        out.planned.admission_rate() * 100.0
    );
    println!(
        "fixed cluster  : {:.1}% of planned+surprise admitted ({} rejected)",
        out.fixed.admission_rate() * 100.0,
        out.fixed.rejected
    );
    println!(
        "hybrid overflow: {:.1}% admitted, {} rented nodes, ${:.4} overflow \
         ({:.1}% of plan cost)",
        out.hybrid.admission_rate() * 100.0,
        out.hybrid.overflow_nodes,
        out.hybrid.overflow_cost,
        100.0 * out.hybrid.overflow_cost / out.hybrid.planned_cost.max(1e-12)
    );
    Ok(())
}

fn cmd_lb(args: &Args) -> Result<()> {
    let input = args.get("input").context("--input required")?;
    let inst = files::load_instance(Path::new(input))?;
    let planner = planner_from(args)?;
    let tr = trim(&inst).instance;
    let (solver, backend) = planner.solver_for(&tr);
    let lb = tlrs::algo::lowerbound::lower_bound(&tr, solver.as_ref())?;
    println!("backend              : {backend}");
    println!("LP dual bound        : {:.6}", lb.lp_bound);
    println!("congestion bound     : {:.6}", lb.congestion_bound);
    println!("LP objective (approx): {:.6}", lb.lp_objective);
    println!("best certified LB    : {:.6}", lb.best());
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    let quick = args.has_flag("quick");
    let out_dir = PathBuf::from(args.get_or("out-dir", "bench_results"));
    std::fs::create_dir_all(&out_dir)?;
    let planner = planner_from(args)?;

    let ids: Vec<&str> = if which == "all" {
        scenarios::all_ids()
    } else {
        scenarios::all_ids().into_iter().filter(|id| *id == which).collect()
    };
    anyhow::ensure!(!ids.is_empty(), "unknown figure '{which}'");

    for id in ids {
        let t0 = std::time::Instant::now();
        if let Some(fig) = scenarios::figure(id, quick) {
            eprintln!(
                "running {id} ({} points x {} seeds)...",
                fig.points.len(),
                fig.seeds.len()
            );
            let res = runner::run_figure(&planner, &fig)?;
            print!("{}", report::render_table(&res));
            report::save_json(&res, &out_dir)?;
        } else {
            let (text, json) = match id {
                "fig1" => special::fig1(&planner)?,
                "fig5" => special::fig5(&planner)?,
                "tab1" => special::tab1(),
                "rt" => special::running_time(&planner, quick)?,
                "ntl" => special::no_timeline(&planner, quick)?,
                other => bail!("unhandled figure {other}"),
            };
            print!("{text}");
            std::fs::write(out_dir.join(format!("{id}.json")), json.to_string())?;
        }
        eprintln!("{id} done in {:?}\n", t0.elapsed());
    }
    eprintln!("--- metrics ---\n{}", planner.metrics.report());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use tlrs::coordinator::runtime::RuntimeConfig;

    let addr = args.get_or("addr", "127.0.0.1:7077");
    let defaults = RuntimeConfig::default();
    let workers = args.get_usize("workers", defaults.workers);
    let timeout_s =
        args.get_f64("request-timeout", defaults.request_timeout.as_secs_f64());
    anyhow::ensure!(
        timeout_s.is_finite() && timeout_s > 0.0,
        "--request-timeout must be a positive number of seconds"
    );
    let cfg = RuntimeConfig {
        workers,
        queue: args.get_usize("queue", 2 * workers),
        request_timeout: std::time::Duration::from_secs_f64(timeout_s),
        max_request_bytes: args.get_usize("max-request-bytes", defaults.max_request_bytes),
        allow_shutdown: args.has_flag("allow-shutdown"),
    };
    let mut planner = planner_from(args)?;
    if planner.route_artifact_serial() {
        eprintln!(
            "note: artifact backend routed through a dedicated solver thread \
             (PJRT client is single-threaded; artifact solves serialize)"
        );
    }
    service::serve_with(Arc::new(planner), &addr, cfg)
}

fn cmd_info() -> Result<()> {
    match tlrs::runtime::Manifest::load(&tlrs::runtime::Manifest::default_dir()) {
        Ok(m) => {
            println!("artifact dir: {}", m.dir.display());
            for b in &m.buckets {
                println!(
                    "  bucket {:<4} N={:<5} M={:<3} T={:<5} D={:<2} chunk={} ({}, {}, {})",
                    b.name, b.n, b.m, b.t, b.d, b.chunk_iters, b.pdhg, b.power, b.penalty
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}); run `make artifacts`"),
    }
    match tlrs::runtime::Engine::cpu() {
        Ok(engine) => println!("PJRT platform: {}", engine.platform()),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    let j = Json::obj(vec![("version", Json::Str(env!("CARGO_PKG_VERSION").into()))]);
    println!("tlrs {}", j.get("version").as_str().unwrap());
    Ok(())
}
