//! tlrs — TL-Rightsizing CLI (the L3 leader entrypoint).
//!
//! Subcommands:
//!   solve    --input inst.json [--algo lp-map-f] [--backend auto] [--replay]
//!   gen      --kind synth|gct [--n N] [--m M] [--dims D] [--horizon T]
//!            [--seed S] --out inst.json [--csv trace.csv]
//!   lb       --input inst.json [--backend auto]
//!   figures  <id|all> [--quick] [--backend auto] [--out-dir bench_results]
//!   serve    [--addr 127.0.0.1:7077] [--backend auto]
//!   info     print artifact manifest and PJRT platform
//!   help

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use tlrs::algo::pipeline;
use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::coordinator::service;
use tlrs::harness::{report, runner, scenarios, special};
use tlrs::io::files;
use tlrs::io::gct_like;
use tlrs::io::synth::{self, SynthParams};
use tlrs::model::trim;
use tlrs::sim::replay::replay;
use tlrs::util::cli::Args;
use tlrs::util::json::Json;

const USAGE: &str = "\
tlrs — cold-start cluster rightsizing for time-limited tasks (CLOUD'21)

USAGE:
  tlrs solve   --input inst.json [--algo <spec>[,<spec>...]]
               [--backend auto|native|artifact|simplex] [--replay] [--out sol.json]
  tlrs gen     --kind synth|gct [--n 1000] [--m 10] [--dims 5] [--horizon 24]
               [--seed 1] [--priced] --out inst.json [--csv trace.csv]
  tlrs lb      --input inst.json [--backend ...]
  tlrs figures <fig1|fig5|fig7a|fig7b|fig7c|fig8a|fig8b|fig9|fig10|fig11|tab1|rt|ntl|all>
               [--quick] [--backend ...] [--out-dir bench_results]
  tlrs ablations [--quick]
  tlrs serve   [--addr 127.0.0.1:7077] [--backend ...]
  tlrs info

ALGO SPECS (--algo, and the service's 'algorithm' field):
  A preset, a pipeline spec, or several specs separated by commas —
  multiple specs race in parallel as a portfolio sharing one LP solve,
  and the min-cost solution wins. The spec token 'portfolio' expands
  to all four presets and may appear inside comma lists.
  spec    := portfolio | <head>[:<fit>][+<refine>]...
  head    := penalty-map | penalty-map-f | lp-map | lp-map-f
           | penalty | penalty-havg | penalty-hmax | lp
  fit     := ff | sim | best            (default: best = race both)
  refine  := fill | ls[:<max_rounds>]   (fill must be the first refine)
  examples: --algo lp+fill+ls    --algo penalty:ff+ls:16
            --algo portfolio     --algo lp-map-f+ls,portfolio
";

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn planner_from(args: &Args) -> Result<Planner> {
    let backend = Backend::parse(&args.get_or("backend", "auto"))?;
    Planner::new(backend)
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "solve" => cmd_solve(args),
        "gen" => cmd_gen(args),
        "lb" => cmd_lb(args),
        "figures" => cmd_figures(args),
        "ablations" => {
            let out = tlrs::harness::ablations::run(args.has_flag("quick"))?;
            print!("{out}");
            Ok(())
        }
        "serve" => cmd_serve(args),
        "info" => cmd_info(),
        "" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn cmd_solve(args: &Args) -> Result<()> {
    let input = args.get("input").context("--input required")?;
    let inst = files::load_instance(Path::new(input))?;
    let planner = planner_from(args)?;
    let algo = args.get_or("algo", "lp-map-f");

    let tr = trim(&inst).instance;
    let (solver, backend) = planner.solver_for(&tr);

    // --algo: one spec runs a single pipeline; 'portfolio' and/or a
    // comma-separated list races the specs in parallel on one LP solve
    // (the service accepts the identical language).
    let portfolio = pipeline::parse_portfolio(&algo)?;

    let t0 = std::time::Instant::now();
    let race = portfolio.run(&tr, solver.as_ref())?;
    let dt = t0.elapsed();
    let report = race.best();
    let solution = &report.solution;
    let lb = race.certified_lb();
    solution
        .verify(&tr)
        .map_err(|v| anyhow::anyhow!("infeasible solution produced: {v:?}"))?;

    let cost = report.cost;
    println!("algorithm      : {} ({backend})", report.label);
    if race.reports.len() > 1 {
        for (i, r) in race.reports.iter().enumerate() {
            let marker = if i == race.winner { " <- winner" } else { "" };
            println!("  raced        : {:<24} cost {:.4}{marker}", r.label, r.cost);
        }
    }
    println!("tasks / types  : {} / {}", tr.n_tasks(), tr.n_types());
    println!("trimmed T      : {}", tr.horizon);
    println!("nodes purchased: {}", solution.nodes.len());
    println!("cluster cost   : {cost:.4}");
    if let Some(lb) = lb {
        println!("lower bound    : {lb:.4}  (normalized cost {:.3})", cost / lb);
    }
    if race.lp_seconds > 0.0 {
        println!(
            "lp solve       : {:.3}s (shared across {} pipeline(s))",
            race.lp_seconds,
            race.reports.len()
        );
    }
    println!("stage times    : {}", report.stage_summary());
    println!("solve time     : {dt:?}");
    if args.has_flag("replay") {
        let rep = replay(&tr, &solution);
        println!(
            "replay         : {} overloads, avg utilization {:.1}%, peak tasks {}",
            rep.overloads,
            rep.avg_utilization * 100.0,
            rep.peak_tasks
        );
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, files::solution_to_json(&solution, &tr).to_string())?;
        println!("solution       : wrote {out}");
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let out = args.get("out").context("--out required")?;
    let seed = args.get_u64("seed", 1);
    let kind = args.get_or("kind", "synth");
    let inst = match kind.as_str() {
        "synth" => {
            let mut p = SynthParams::default();
            p.n = args.get_usize("n", p.n);
            p.m = args.get_usize("m", p.m);
            p.dims = args.get_usize("dims", p.dims);
            p.horizon = args.get_usize("horizon", p.horizon as usize) as u32;
            synth::generate(&p, seed)
        }
        "gct" => {
            let trace = gct_like::generate_trace(13_000, 0x6c7_2019);
            let n = args.get_usize("n", 1000);
            let m = args.get_usize("m", 10);
            let mut inst = trace.sample_scenario(n, m, seed);
            if !args.has_flag("priced") {
                tlrs::model::CostModel::homogeneous(inst.dims())
                    .apply(&mut inst.node_types);
            }
            inst
        }
        other => bail!("unknown --kind '{other}'"),
    };
    files::save_instance(&inst, Path::new(out))?;
    println!("wrote {} ({} tasks, {} node-types)", out, inst.n_tasks(), inst.n_types());
    if let Some(csv) = args.get("csv") {
        files::save_trace_csv(&inst.tasks, Path::new(csv))?;
        println!("wrote {csv}");
    }
    Ok(())
}

fn cmd_lb(args: &Args) -> Result<()> {
    let input = args.get("input").context("--input required")?;
    let inst = files::load_instance(Path::new(input))?;
    let planner = planner_from(args)?;
    let tr = trim(&inst).instance;
    let (solver, backend) = planner.solver_for(&tr);
    let lb = tlrs::algo::lowerbound::lower_bound(&tr, solver.as_ref())?;
    println!("backend              : {backend}");
    println!("LP dual bound        : {:.6}", lb.lp_bound);
    println!("congestion bound     : {:.6}", lb.congestion_bound);
    println!("LP objective (approx): {:.6}", lb.lp_objective);
    println!("best certified LB    : {:.6}", lb.best());
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    let quick = args.has_flag("quick");
    let out_dir = PathBuf::from(args.get_or("out-dir", "bench_results"));
    std::fs::create_dir_all(&out_dir)?;
    let planner = planner_from(args)?;

    let ids: Vec<&str> = if which == "all" {
        scenarios::all_ids()
    } else {
        scenarios::all_ids().into_iter().filter(|id| *id == which).collect()
    };
    anyhow::ensure!(!ids.is_empty(), "unknown figure '{which}'");

    for id in ids {
        let t0 = std::time::Instant::now();
        if let Some(fig) = scenarios::figure(id, quick) {
            eprintln!(
                "running {id} ({} points x {} seeds)...",
                fig.points.len(),
                fig.seeds.len()
            );
            let res = runner::run_figure(&planner, &fig)?;
            print!("{}", report::render_table(&res));
            report::save_json(&res, &out_dir)?;
        } else {
            let (text, json) = match id {
                "fig1" => special::fig1(&planner)?,
                "fig5" => special::fig5(&planner)?,
                "tab1" => special::tab1(),
                "rt" => special::running_time(&planner, quick)?,
                "ntl" => special::no_timeline(&planner, quick)?,
                other => bail!("unhandled figure {other}"),
            };
            print!("{text}");
            std::fs::write(out_dir.join(format!("{id}.json")), json.to_string())?;
        }
        eprintln!("{id} done in {:?}\n", t0.elapsed());
    }
    eprintln!("--- metrics ---\n{}", planner.metrics.report());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7077");
    let planner = Arc::new(planner_from(args)?);
    service::serve(planner, &addr)
}

fn cmd_info() -> Result<()> {
    match tlrs::runtime::Manifest::load(&tlrs::runtime::Manifest::default_dir()) {
        Ok(m) => {
            println!("artifact dir: {}", m.dir.display());
            for b in &m.buckets {
                println!(
                    "  bucket {:<4} N={:<5} M={:<3} T={:<5} D={:<2} chunk={} ({}, {}, {})",
                    b.name, b.n, b.m, b.t, b.d, b.chunk_iters, b.pdhg, b.power, b.penalty
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}); run `make artifacts`"),
    }
    match tlrs::runtime::Engine::cpu() {
        Ok(engine) => println!("PJRT platform: {}", engine.platform()),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    let j = Json::obj(vec![("version", Json::Str(env!("CARGO_PKG_VERSION").into()))]);
    println!("tlrs {}", j.get("version").as_str().unwrap());
    Ok(())
}
