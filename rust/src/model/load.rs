//! Shared load-profile subsystem: every consumer of a node's per-(t, d)
//! usage — greedy placement, cross-fill, online placement, local search,
//! exact search and `Solution::verify` — speaks to one [`Profile`]
//! abstraction with two implementations:
//!
//!  * [`LoadProfile`] — the indexed production path: one lazy segment
//!    tree per dimension maintaining `(max, sum, sumsq)` aggregates under
//!    range-add, so feasibility checks, task add/remove, similarity
//!    scoring and peak queries cost O(D·log T) instead of O(span·D) and
//!    O(T·D).
//!  * [`DenseProfile`] — the seed's dense per-timeslot array, kept as the
//!    reference path for property tests and as the benchmark baseline.
//!
//! The `sumsq` aggregate is what makes cosine similarity recoverable from
//! range queries alone: adding a constant `c` over a segment of length
//! `len` updates `sumsq += 2c·sum + c²·len`, and for a task window of
//! length `L` in dimension `d`,
//! `Σ (cap-u)² = L·cap² - 2·cap·Σu + Σu²`.
//!
//! Tasks carry piecewise-constant [`DemandProfile`]s (`model::task`):
//! every task-level operation below iterates the task's demand segments
//! and issues one range operation per (segment, dimension) — O(S·D·log T)
//! on the indexed backend, where the flat case S = 1 reproduces the
//! original flat-task arithmetic operation-for-operation.
//!
//! `DenseProfile` overrides the task-level operations (`fits`,
//! `add_task`, `similarity`, ...) with the seed's exact t-major loops,
//! so the property tests in `tests/prop_invariants.rs` compare the
//! indexed code the solvers run against the seed's behavior, not
//! against itself.

use super::task::Task;
use super::EPS;

/// A node's per-dimension usage over the timeline, with the query set the
/// placement stack needs. `lo..=hi` ranges are inclusive timeslots.
pub trait Profile: Clone + std::fmt::Debug {
    /// Empty profile over `n_slots` timeslots with the given capacity.
    fn new(n_slots: usize, cap: Vec<f64>) -> Self;

    /// Capacity vector of the owning node.
    fn cap(&self) -> &[f64];

    /// Replace the capacity vector (same dimensionality). Usage is kept —
    /// local search uses this when downgrading a node's type.
    fn set_cap(&mut self, cap: Vec<f64>);

    /// Add `c` to dimension `d` over timeslots `lo..=hi`.
    fn range_add(&mut self, d: usize, lo: usize, hi: usize, c: f64);

    /// Max usage in dimension `d` over `lo..=hi`.
    fn window_max(&self, d: usize, lo: usize, hi: usize) -> f64;

    /// `(Σ usage, Σ usage²)` in dimension `d` over `lo..=hi`.
    fn window_sums(&self, d: usize, lo: usize, hi: usize) -> (f64, f64);

    /// Max usage in dimension `d` over the whole timeline. O(1) on the
    /// indexed backend — the root of the max tree.
    fn peak(&self, d: usize) -> f64;

    /// Ascending timeslots where usage in `d` strictly exceeds
    /// `threshold`, with their loads. Output-sensitive on the indexed
    /// backend: only subtrees whose max exceeds the threshold are visited.
    fn overloads(&self, d: usize, threshold: f64) -> Vec<(usize, f64)>;

    // ---- derived task-level operations (shared by both backends) ----

    /// Number of resource dimensions D.
    fn dims(&self) -> usize {
        self.cap().len()
    }

    /// Aggregate the task's demand into the profile: one range-add per
    /// (segment, dimension) — O(S·D·log T) on the indexed backend, which
    /// for the flat case (S = 1) is the seed's O(D·log T).
    fn add_task(&mut self, task: &Task) {
        for seg in task.segments() {
            for d in 0..self.dims() {
                self.range_add(d, seg.start as usize, seg.end as usize, seg.demand[d]);
            }
        }
    }

    /// Remove a previously added task's demand.
    fn remove_task(&mut self, task: &Task) {
        for seg in task.segments() {
            for d in 0..self.dims() {
                self.range_add(d, seg.start as usize, seg.end as usize, -seg.demand[d]);
            }
        }
    }

    /// Does the task fit without violating capacity anywhere in its span?
    ///
    /// Fast path (candidate pruning): when the whole-timeline peak leaves
    /// headroom for the task's *peak* demand in every dimension, the task
    /// surely fits — O(D) with no windowed query. Otherwise fall back to
    /// the exact per-segment windowed maxima (each segment checked
    /// against its own demand), O(S·D·log T) on the indexed backend.
    fn fits(&self, task: &Task) -> bool {
        let cap = self.cap();
        let peak_dem = task.peak();
        let mut sure = true;
        for (d, &c) in cap.iter().enumerate() {
            if peak_dem[d] + self.peak(d) > c + EPS {
                sure = false;
                break;
            }
        }
        if sure {
            return true;
        }
        task.segments().iter().all(|seg| {
            let (lo, hi) = (seg.start as usize, seg.end as usize);
            cap.iter()
                .enumerate()
                .all(|(d, &c)| self.window_max(d, lo, hi) + seg.demand[d] <= c + EPS)
        })
    }

    /// Cosine similarity between the capacity-normalized demand and
    /// remaining-capacity vectors aggregated over the task span (paper
    /// section III, "Alternative Mapping and Fitting Policies"),
    /// recovered from window sums: for a window of length `L`,
    /// `Σ rem = (L·cap - Σu)/cap` and
    /// `Σ rem² = (L·cap² - 2·cap·Σu + Σu²)/cap²`.
    ///
    /// The seed's dense loop (kept verbatim as `DenseProfile`'s override)
    /// clamps per-slot remainders at zero; `fits` bounds usage to
    /// capacity + EPS, so on the feasible profiles the solvers actually
    /// build, clamping is inert and the two computations agree.
    fn similarity(&self, task: &Task) -> f64 {
        let cap = self.cap();
        let (mut dot, mut nrm_d, mut nrm_r) = (0.0f64, 0.0f64, 0.0f64);
        for (d, &c) in cap.iter().enumerate() {
            // one windowed-sum query per segment: the demand is constant
            // within a segment, so the per-slot cosine terms aggregate
            // exactly as in the flat derivation, window by window
            for seg in task.segments() {
                let (lo, hi) = (seg.start as usize, seg.end as usize);
                let len = (hi - lo + 1) as f64;
                let (sum, sumsq) = self.window_sums(d, lo, hi);
                let dem = seg.demand[d] / c;
                dot += dem * (len * c - sum) / c;
                nrm_d += dem * dem * len;
                nrm_r += (len * c * c - 2.0 * c * sum + sumsq) / (c * c);
            }
        }
        if nrm_d <= 0.0 || nrm_r <= 0.0 {
            return 0.0;
        }
        dot / (nrm_d.sqrt() * nrm_r.sqrt())
    }

    /// Peak load fraction over the busiest (t, d).
    fn peak_utilization(&self) -> f64 {
        let cap = self.cap();
        cap.iter()
            .enumerate()
            .map(|(d, &c)| self.peak(d) / c)
            .fold(0.0f64, f64::max)
    }

    /// Per-dimension peak usage over the whole timeline.
    fn peaks(&self) -> Vec<f64> {
        (0..self.dims()).map(|d| self.peak(d)).collect()
    }
}

// ---------------------------------------------------------------------------
// Indexed backend
// ---------------------------------------------------------------------------

/// Lazy segment tree over one dimension: range-add with `(max, sum,
/// sumsq)` aggregates.
///
/// Conventions: aggregates stored at a node are *true* subtree values
/// (they already include the node's own pending `lazy`); `lazy` is the
/// uniform add not yet folded into the children's aggregates. Queries are
/// therefore immutable — they carry the sum of ancestor lazies down the
/// recursion instead of pushing — and only `add` rebalances the arrays.
#[derive(Clone, Debug)]
struct SegTree {
    /// Number of leaves: the smallest power of two >= n_slots.
    size: usize,
    max: Vec<f64>,
    sum: Vec<f64>,
    sumsq: Vec<f64>,
    lazy: Vec<f64>,
}

impl SegTree {
    fn new(n_slots: usize) -> Self {
        let size = n_slots.next_power_of_two().max(1);
        SegTree {
            size,
            max: vec![0.0; 2 * size],
            sum: vec![0.0; 2 * size],
            sumsq: vec![0.0; 2 * size],
            // only internal nodes (index < size) carry pending adds:
            // leaves get them folded into their aggregates immediately
            lazy: vec![0.0; size],
        }
    }

    /// Apply a uniform add of `c` over all `len` slots covered by `node`.
    /// Order matters: `sumsq` must read the pre-update `sum`.
    fn apply(&mut self, node: usize, len: usize, c: f64) {
        let s = self.sum[node];
        self.sumsq[node] += 2.0 * c * s + c * c * len as f64;
        self.sum[node] = s + c * len as f64;
        self.max[node] += c;
        if node < self.size {
            self.lazy[node] += c;
        }
    }

    fn push(&mut self, node: usize, len: usize) {
        let c = self.lazy[node];
        if c != 0.0 {
            self.apply(2 * node, len / 2, c);
            self.apply(2 * node + 1, len / 2, c);
            self.lazy[node] = 0.0;
        }
    }

    fn pull(&mut self, node: usize) {
        self.max[node] = self.max[2 * node].max(self.max[2 * node + 1]);
        self.sum[node] = self.sum[2 * node] + self.sum[2 * node + 1];
        self.sumsq[node] = self.sumsq[2 * node] + self.sumsq[2 * node + 1];
    }

    fn add(&mut self, l: usize, r: usize, c: f64) {
        self.add_rec(1, 0, self.size - 1, l, r, c);
    }

    fn add_rec(&mut self, node: usize, lo: usize, hi: usize, l: usize, r: usize, c: f64) {
        if r < lo || hi < l {
            return;
        }
        if l <= lo && hi <= r {
            self.apply(node, hi - lo + 1, c);
            return;
        }
        self.push(node, hi - lo + 1);
        let mid = lo + (hi - lo) / 2;
        self.add_rec(2 * node, lo, mid, l, r, c);
        self.add_rec(2 * node + 1, mid + 1, hi, l, r, c);
        self.pull(node);
    }

    fn query_max(&self, l: usize, r: usize) -> f64 {
        self.max_rec(1, 0, self.size - 1, l, r, 0.0)
    }

    fn max_rec(&self, node: usize, lo: usize, hi: usize, l: usize, r: usize, acc: f64) -> f64 {
        if r < lo || hi < l {
            return f64::NEG_INFINITY;
        }
        if l <= lo && hi <= r {
            return self.max[node] + acc;
        }
        let acc = acc + self.lazy[node];
        let mid = lo + (hi - lo) / 2;
        self.max_rec(2 * node, lo, mid, l, r, acc)
            .max(self.max_rec(2 * node + 1, mid + 1, hi, l, r, acc))
    }

    fn query_sums(&self, l: usize, r: usize) -> (f64, f64) {
        self.sums_rec(1, 0, self.size - 1, l, r, 0.0)
    }

    fn sums_rec(
        &self,
        node: usize,
        lo: usize,
        hi: usize,
        l: usize,
        r: usize,
        acc: f64,
    ) -> (f64, f64) {
        if r < lo || hi < l {
            return (0.0, 0.0);
        }
        if l <= lo && hi <= r {
            let len = (hi - lo + 1) as f64;
            let s = self.sum[node];
            return (s + acc * len, self.sumsq[node] + 2.0 * acc * s + acc * acc * len);
        }
        let acc = acc + self.lazy[node];
        let mid = lo + (hi - lo) / 2;
        let (s1, q1) = self.sums_rec(2 * node, lo, mid, l, r, acc);
        let (s2, q2) = self.sums_rec(2 * node + 1, mid + 1, hi, l, r, acc);
        (s1 + s2, q1 + q2)
    }

    /// Collect ascending slots with value strictly above `threshold`.
    /// `n_slots` bounds the walk to real (non-padding) leaves.
    fn collect_over(
        &self,
        node: usize,
        lo: usize,
        hi: usize,
        acc: f64,
        threshold: f64,
        n_slots: usize,
        out: &mut Vec<(usize, f64)>,
    ) {
        if lo >= n_slots || self.max[node] + acc <= threshold {
            return;
        }
        if lo == hi {
            // leaf: its sum over one slot is the slot's value
            out.push((lo, self.sum[node] + acc));
            return;
        }
        let acc = acc + self.lazy[node];
        let mid = lo + (hi - lo) / 2;
        self.collect_over(2 * node, lo, mid, acc, threshold, n_slots, out);
        self.collect_over(2 * node + 1, mid + 1, hi, acc, threshold, n_slots, out);
    }
}

/// Indexed load profile: one lazy segment tree per dimension. All range
/// operations are O(log T); whole-timeline peaks are O(1).
#[derive(Clone, Debug)]
pub struct LoadProfile {
    cap: Vec<f64>,
    n_slots: usize,
    trees: Vec<SegTree>,
}

impl Profile for LoadProfile {
    fn new(n_slots: usize, cap: Vec<f64>) -> Self {
        assert!(n_slots > 0, "empty timeline");
        assert!(!cap.is_empty(), "empty capacity");
        let trees = (0..cap.len()).map(|_| SegTree::new(n_slots)).collect();
        LoadProfile { cap, n_slots, trees }
    }

    fn cap(&self) -> &[f64] {
        &self.cap
    }

    fn set_cap(&mut self, cap: Vec<f64>) {
        assert_eq!(cap.len(), self.cap.len(), "capacity dims changed");
        self.cap = cap;
    }

    fn range_add(&mut self, d: usize, lo: usize, hi: usize, c: f64) {
        // hard assert: the dense path panics on out-of-range slots via
        // indexing; the tree would silently clip instead, so keep the
        // same loud failure mode (O(1) next to the O(log T) update)
        assert!(
            lo <= hi && hi < self.n_slots,
            "range {lo}..={hi} outside timeline of {} slots",
            self.n_slots
        );
        self.trees[d].add(lo, hi, c);
    }

    fn window_max(&self, d: usize, lo: usize, hi: usize) -> f64 {
        self.trees[d].query_max(lo, hi)
    }

    fn window_sums(&self, d: usize, lo: usize, hi: usize) -> (f64, f64) {
        self.trees[d].query_sums(lo, hi)
    }

    fn peak(&self, d: usize) -> f64 {
        // Padding leaves beyond n_slots hold zero usage; real usage is
        // non-negative, so the root max is the true timeline peak.
        self.trees[d].max[1]
    }

    fn overloads(&self, d: usize, threshold: f64) -> Vec<(usize, f64)> {
        let mut out = Vec::new();
        let tree = &self.trees[d];
        tree.collect_over(1, 0, tree.size - 1, 0.0, threshold, self.n_slots, &mut out);
        out
    }
}

// ---------------------------------------------------------------------------
// Dense reference backend
// ---------------------------------------------------------------------------

/// Dense reference profile: the seed's per-(t, d) usage array with its
/// exact t-major update and scan order. O(span·D) updates, O(T·D) peaks.
/// Kept as the property-test reference and the benchmark baseline.
#[derive(Clone, Debug)]
pub struct DenseProfile {
    cap: Vec<f64>,
    n_slots: usize,
    /// usage[t * dims + d]
    usage: Vec<f64>,
}

impl Profile for DenseProfile {
    fn new(n_slots: usize, cap: Vec<f64>) -> Self {
        assert!(n_slots > 0, "empty timeline");
        assert!(!cap.is_empty(), "empty capacity");
        DenseProfile { usage: vec![0.0; n_slots * cap.len()], cap, n_slots }
    }

    fn cap(&self) -> &[f64] {
        &self.cap
    }

    fn set_cap(&mut self, cap: Vec<f64>) {
        assert_eq!(cap.len(), self.cap.len(), "capacity dims changed");
        self.cap = cap;
    }

    fn range_add(&mut self, d: usize, lo: usize, hi: usize, c: f64) {
        let dims = self.cap.len();
        for t in lo..=hi {
            self.usage[t * dims + d] += c;
        }
    }

    fn window_max(&self, d: usize, lo: usize, hi: usize) -> f64 {
        let dims = self.cap.len();
        (lo..=hi)
            .map(|t| self.usage[t * dims + d])
            .fold(f64::NEG_INFINITY, f64::max)
    }

    fn window_sums(&self, d: usize, lo: usize, hi: usize) -> (f64, f64) {
        let dims = self.cap.len();
        let (mut s, mut q) = (0.0f64, 0.0f64);
        for t in lo..=hi {
            let v = self.usage[t * dims + d];
            s += v;
            q += v * v;
        }
        (s, q)
    }

    fn peak(&self, d: usize) -> f64 {
        self.window_max(d, 0, self.n_slots - 1)
    }

    fn overloads(&self, d: usize, threshold: f64) -> Vec<(usize, f64)> {
        let dims = self.cap.len();
        (0..self.n_slots)
            .filter_map(|t| {
                let v = self.usage[t * dims + d];
                (v > threshold).then_some((t, v))
            })
            .collect()
    }

    /// Seed-faithful dense feasibility scan: t-major within each segment,
    /// per-slot compare, no peak fast path (computing the peak would
    /// itself cost O(T·D)).
    fn fits(&self, task: &Task) -> bool {
        let dims = self.cap.len();
        for seg in task.segments() {
            for t in seg.start as usize..=seg.end as usize {
                let base = t * dims;
                for (d, &c) in self.cap.iter().enumerate() {
                    if self.usage[base + d] + seg.demand[d] > c + EPS {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Seed-faithful per-slot cosine loop with remainders clamped at
    /// zero — the reference the indexed sum/sumsq derivation is
    /// property-tested against. The two agree exactly on feasible
    /// profiles (clamping can only trigger on slots loaded past capacity,
    /// which `fits` bounds to the EPS tolerance).
    fn similarity(&self, task: &Task) -> f64 {
        let dims = self.cap.len();
        let (mut dot, mut nrm_d, mut nrm_r) = (0.0f64, 0.0f64, 0.0f64);
        for seg in task.segments() {
            for t in seg.start as usize..=seg.end as usize {
                let base = t * dims;
                for (d, &c) in self.cap.iter().enumerate() {
                    let dem = seg.demand[d] / c;
                    let rem = (c - self.usage[base + d]).max(0.0) / c;
                    dot += dem * rem;
                    nrm_d += dem * dem;
                    nrm_r += rem * rem;
                }
            }
        }
        if nrm_d <= 0.0 || nrm_r <= 0.0 {
            return 0.0;
        }
        dot / (nrm_d.sqrt() * nrm_r.sqrt())
    }

    /// Dense add in the seed's t-major order (FP-faithful).
    fn add_task(&mut self, task: &Task) {
        let dims = self.cap.len();
        for seg in task.segments() {
            for t in seg.start as usize..=seg.end as usize {
                let base = t * dims;
                for d in 0..dims {
                    self.usage[base + d] += seg.demand[d];
                }
            }
        }
    }

    fn remove_task(&mut self, task: &Task) {
        let dims = self.cap.len();
        for seg in task.segments() {
            for t in seg.start as usize..=seg.end as usize {
                let base = t * dims;
                for d in 0..dims {
                    self.usage[base + d] -= seg.demand[d];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(demand: Vec<f64>, start: u32, end: u32) -> Task {
        Task::new(0, demand, start, end)
    }

    #[test]
    fn segtree_matches_brute_force() {
        // deterministic mixed add/query workload against a flat array
        let n = 37usize; // deliberately not a power of two
        let mut tree = SegTree::new(n);
        let mut flat = vec![0.0f64; n];
        let ops: [(usize, usize, f64); 7] = [
            (0, 36, 0.25),
            (3, 11, 1.5),
            (11, 11, -0.5),
            (20, 30, 0.125),
            (0, 5, 2.0),
            (30, 36, 0.75),
            (5, 25, -0.125),
        ];
        for &(l, r, c) in &ops {
            tree.add(l, r, c);
            for t in l..=r {
                flat[t] += c;
            }
            for &(ql, qr) in &[(0usize, n - 1), (2, 9), (10, 20), (25, 36), (7, 7)] {
                let want_max = flat[ql..=qr].iter().copied().fold(f64::NEG_INFINITY, f64::max);
                let want_sum: f64 = flat[ql..=qr].iter().sum();
                let want_sq: f64 = flat[ql..=qr].iter().map(|v| v * v).sum();
                assert!((tree.query_max(ql, qr) - want_max).abs() < 1e-12, "max {ql}..={qr}");
                let (s, q) = tree.query_sums(ql, qr);
                assert!((s - want_sum).abs() < 1e-9, "sum {ql}..={qr}: {s} vs {want_sum}");
                assert!((q - want_sq).abs() < 1e-9, "sumsq {ql}..={qr}: {q} vs {want_sq}");
            }
        }
        // root max is the whole-array peak
        let peak = flat.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        assert!((tree.max[1] - peak).abs() < 1e-12);
    }

    #[test]
    fn segtree_overload_enumeration() {
        let n = 10usize;
        let mut tree = SegTree::new(n);
        tree.add(2, 5, 1.0);
        tree.add(4, 8, 1.0);
        let mut out = Vec::new();
        tree.collect_over(1, 0, tree.size - 1, 0.0, 1.5, n, &mut out);
        let slots: Vec<usize> = out.iter().map(|&(t, _)| t).collect();
        assert_eq!(slots, vec![4, 5]);
        for &(_, v) in &out {
            assert!((v - 2.0).abs() < 1e-12);
        }
        // threshold above the peak: nothing
        out.clear();
        tree.collect_over(1, 0, tree.size - 1, 0.0, 2.5, n, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn profiles_agree_on_scripted_ops() {
        let cap = vec![1.0, 0.5];
        let mut idx: LoadProfile = Profile::new(12, cap.clone());
        let mut dense: DenseProfile = Profile::new(12, cap.clone());
        let tasks = [
            task(vec![0.3, 0.1], 0, 7),
            task(vec![0.4, 0.2], 2, 4),
            task(vec![0.2, 0.15], 4, 11),
        ];
        for t in &tasks {
            idx.add_task(t);
            dense.add_task(t);
        }
        let probe = task(vec![0.35, 0.2], 3, 6);
        assert_eq!(idx.fits(&probe), dense.fits(&probe));
        assert!((idx.similarity(&probe) - dense.similarity(&probe)).abs() < 1e-12);
        for d in 0..2 {
            assert!((idx.peak(d) - dense.peak(d)).abs() < 1e-12);
            assert!((idx.window_max(d, 3, 6) - dense.window_max(d, 3, 6)).abs() < 1e-12);
            let (s1, q1) = idx.window_sums(d, 2, 9);
            let (s2, q2) = dense.window_sums(d, 2, 9);
            assert!((s1 - s2).abs() < 1e-12 && (q1 - q2).abs() < 1e-12);
        }
        idx.remove_task(&tasks[1]);
        dense.remove_task(&tasks[1]);
        assert!((idx.peak(0) - dense.peak(0)).abs() < 1e-12);
        assert_eq!(idx.fits(&probe), dense.fits(&probe));
    }

    #[test]
    fn fits_fast_path_and_exact_path_agree() {
        // a profile busy outside the probe window: the fast accept fails
        // (timeline peak too high) but the windowed check must admit
        let mut p: LoadProfile = Profile::new(16, vec![1.0]);
        p.add_task(&task(vec![0.9], 0, 3));
        let probe = task(vec![0.8], 8, 12);
        assert!(p.fits(&probe));
        // and inside the busy window it must reject
        let clash = task(vec![0.2], 1, 2);
        assert!(!p.fits(&clash));
        // fast accept: empty window everywhere
        let tiny = task(vec![0.05], 0, 15);
        assert!(p.fits(&tiny));
    }

    #[test]
    fn similarity_matches_seed_dense_loop() {
        // recompute the seed's per-slot cosine loop by hand and compare
        let cap = vec![1.0, 0.8];
        let mut p: LoadProfile = Profile::new(8, cap.clone());
        let held = task(vec![0.5, 0.1], 1, 5);
        p.add_task(&held);
        let probe = task(vec![0.2, 0.4], 0, 6);
        let mut usage = vec![0.0f64; 8 * 2];
        for t in 1..=5usize {
            usage[t * 2] += 0.5;
            usage[t * 2 + 1] += 0.1;
        }
        let (mut dot, mut nd, mut nr) = (0.0f64, 0.0f64, 0.0f64);
        for t in 0..=6usize {
            for d in 0..2 {
                let dem = probe.peak()[d] / cap[d];
                let rem = (cap[d] - usage[t * 2 + d]).max(0.0) / cap[d];
                dot += dem * rem;
                nd += dem * dem;
                nr += rem * rem;
            }
        }
        let want = dot / (nd.sqrt() * nr.sqrt());
        assert!((p.similarity(&probe) - want).abs() < 1e-12);
    }

    #[test]
    fn set_cap_rescales_feasibility() {
        let mut p: LoadProfile = Profile::new(4, vec![0.5]);
        p.add_task(&task(vec![0.4], 0, 3));
        assert!(!p.fits(&task(vec![0.3], 1, 2)));
        p.set_cap(vec![1.0]);
        assert!(p.fits(&task(vec![0.3], 1, 2)));
        assert!((p.peak_utilization() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn piecewise_task_matches_flat_split() {
        use crate::model::task::DemandSeg;
        // a shaped task must load the profile exactly like the equivalent
        // set of flat per-segment tasks, on both backends
        let shaped = Task::piecewise(
            0,
            vec![
                DemandSeg { start: 1, end: 3, demand: vec![0.2, 0.5] },
                DemandSeg { start: 4, end: 6, demand: vec![0.7, 0.1] },
            ],
        );
        let split = [
            task(vec![0.2, 0.5], 1, 3),
            task(vec![0.7, 0.1], 4, 6),
        ];
        let cap = vec![1.0, 1.0];
        let mut a: LoadProfile = Profile::new(8, cap.clone());
        let mut b: LoadProfile = Profile::new(8, cap.clone());
        let mut d: DenseProfile = Profile::new(8, cap.clone());
        a.add_task(&shaped);
        d.add_task(&shaped);
        for t in &split {
            b.add_task(t);
        }
        for dim in 0..2 {
            for t in 0..8 {
                let (sa, _) = a.window_sums(dim, t, t);
                let (sb, _) = b.window_sums(dim, t, t);
                let (sd, _) = d.window_sums(dim, t, t);
                assert!((sa - sb).abs() < 1e-12, "dim {dim} slot {t}");
                assert!((sa - sd).abs() < 1e-12, "dim {dim} slot {t}");
            }
        }
        // per-segment feasibility: a probe clashing only with the second
        // window is rejected, one fitting beside the peak is accepted
        assert!(!a.fits(&task(vec![0.4, 0.4], 4, 5)));
        assert!(a.fits(&task(vec![0.4, 0.4], 1, 3)));
        // shaped probe against a loaded profile: fits iff every segment fits
        let probe = Task::piecewise(
            1,
            vec![
                DemandSeg { start: 1, end: 3, demand: vec![0.7, 0.4] },
                DemandSeg { start: 4, end: 6, demand: vec![0.2, 0.4] },
            ],
        );
        assert!(a.fits(&probe));
        assert_eq!(a.fits(&probe), d.fits(&probe));
        let clash = Task::piecewise(
            2,
            vec![
                DemandSeg { start: 1, end: 3, demand: vec![0.7, 0.4] },
                DemandSeg { start: 4, end: 6, demand: vec![0.4, 0.4] },
            ],
        );
        assert!(!a.fits(&clash));
        assert_eq!(a.fits(&clash), d.fits(&clash));
        // similarity agrees across backends on shaped probes too
        assert!((a.similarity(&probe) - d.similarity(&probe)).abs() < 1e-9);
        // remove restores the empty profile
        a.remove_task(&shaped);
        assert!(a.peak(0).abs() < 1e-12 && a.peak(1).abs() < 1e-12);
    }

    #[test]
    fn single_slot_timeline() {
        let mut p: LoadProfile = Profile::new(1, vec![1.0]);
        p.add_task(&task(vec![0.6], 0, 0));
        assert!((p.peak(0) - 0.6).abs() < 1e-12);
        assert!(p.fits(&task(vec![0.4], 0, 0)));
        assert!(!p.fits(&task(vec![0.5], 0, 0)));
        assert_eq!(p.overloads(0, 0.5).len(), 1);
    }
}
