//! Shared load-profile subsystem: every consumer of a node's per-(t, d)
//! usage — greedy placement, cross-fill, online placement, local search,
//! exact search and `Solution::verify` — speaks to one [`Profile`]
//! abstraction with two implementations:
//!
//!  * [`LoadProfile`] — the indexed production path: the lazy segment
//!    trees of all D dimensions flattened into one SoA [`SegStore`]
//!    (five contiguous buffers — max, min, sum, sumsq, lazy — in
//!    dim-major blocks), so feasibility checks, task add/remove,
//!    similarity scoring and peak queries cost O(D·log T) instead of
//!    O(span·D) and O(T·D), and building a node profile costs five
//!    allocations instead of 4·D.
//!  * [`DenseProfile`] — the seed's dense per-timeslot array, kept as the
//!    reference path for property tests and as the benchmark baseline.
//!
//! The `sumsq` aggregate is what makes cosine similarity recoverable from
//! range queries alone: adding a constant `c` over a segment of length
//! `len` updates `sumsq += 2c·sum + c²·len`, and for a task window of
//! length `L` in dimension `d`,
//! `Σ (cap-u)² = L·cap² - 2·cap·Σu + Σu²`.
//!
//! The `min` aggregate gives the timeline *floor* per dimension in O(1)
//! (padding leaves are pinned to +∞ so the root min covers real slots
//! only). `LoadProfile::fits` uses it as an exact sure-*reject*: when
//! even the node's quietest slot plus the task's quietest segment
//! overflows some dimension, no windowed check can pass — the full-node
//! prefix that first-fit rescans is dismissed in O(D) instead of
//! O(S·D·log T). Together with the O(1) peaks it also powers the
//! bucketed-headroom candidate index in `algo/placement.rs`
//! ([`Profile::CHEAP_PEAKS`]).
//!
//! Tasks carry piecewise-constant [`DemandProfile`]s (`model::task`):
//! every task-level operation below iterates the task's demand segments
//! and issues one range operation per (segment, dimension) — O(S·D·log T)
//! on the indexed backend, where the flat case S = 1 reproduces the
//! original flat-task arithmetic operation-for-operation.
//!
//! `DenseProfile` overrides the task-level operations (`fits`,
//! `add_task`, `similarity`, ...) with the seed's exact t-major loops,
//! so the property tests in `tests/prop_invariants.rs` compare the
//! indexed code the solvers run against the seed's behavior, not
//! against itself.
//!
//! [`DemandProfile`]: super::task::DemandProfile

use super::task::Task;
use super::EPS;

/// A node's per-dimension usage over the timeline, with the query set the
/// placement stack needs. `lo..=hi` ranges are inclusive timeslots.
pub trait Profile: Clone + std::fmt::Debug {
    /// True when whole-timeline [`Profile::peak`] queries are O(1):
    /// placement then maintains the bucketed-headroom candidate index
    /// (recomputing every node's headroom per add would otherwise turn
    /// the index into the O(T·D) scan it replaces).
    const CHEAP_PEAKS: bool = false;

    /// Empty profile over `n_slots` timeslots with the given capacity.
    fn new(n_slots: usize, cap: Vec<f64>) -> Self;

    /// Capacity vector of the owning node.
    fn cap(&self) -> &[f64];

    /// Replace the capacity vector (same dimensionality). Usage is kept —
    /// local search uses this when downgrading a node's type.
    fn set_cap(&mut self, cap: Vec<f64>);

    /// Add `c` to dimension `d` over timeslots `lo..=hi`.
    fn range_add(&mut self, d: usize, lo: usize, hi: usize, c: f64);

    /// Max usage in dimension `d` over `lo..=hi`.
    fn window_max(&self, d: usize, lo: usize, hi: usize) -> f64;

    /// `(Σ usage, Σ usage²)` in dimension `d` over `lo..=hi`.
    fn window_sums(&self, d: usize, lo: usize, hi: usize) -> (f64, f64);

    /// Max usage in dimension `d` over the whole timeline. O(1) on the
    /// indexed backend — the root of the max tree.
    fn peak(&self, d: usize) -> f64;

    /// Ascending timeslots where usage in `d` strictly exceeds
    /// `threshold`, with their loads. Output-sensitive on the indexed
    /// backend: only subtrees whose max exceeds the threshold are visited.
    fn overloads(&self, d: usize, threshold: f64) -> Vec<(usize, f64)>;

    // ---- derived task-level operations (shared by both backends) ----

    /// Number of resource dimensions D.
    fn dims(&self) -> usize {
        self.cap().len()
    }

    /// Aggregate the task's demand into the profile: one range-add per
    /// (segment, dimension) — O(S·D·log T) on the indexed backend, which
    /// for the flat case (S = 1) is the seed's O(D·log T).
    fn add_task(&mut self, task: &Task) {
        for seg in task.segments() {
            for d in 0..self.dims() {
                self.range_add(d, seg.start as usize, seg.end as usize, seg.demand[d]);
            }
        }
    }

    /// Remove a previously added task's demand.
    fn remove_task(&mut self, task: &Task) {
        for seg in task.segments() {
            for d in 0..self.dims() {
                self.range_add(d, seg.start as usize, seg.end as usize, -seg.demand[d]);
            }
        }
    }

    /// Does the task fit without violating capacity anywhere in its span?
    ///
    /// Fast path (candidate pruning): when the whole-timeline peak leaves
    /// headroom for the task's *peak* demand in every dimension, the task
    /// surely fits — O(D) with no windowed query. Otherwise fall back to
    /// the exact per-segment windowed maxima (each segment checked
    /// against its own demand), O(S·D·log T) on the indexed backend.
    fn fits(&self, task: &Task) -> bool {
        let cap = self.cap();
        let peak_dem = task.peak();
        let mut sure = true;
        for (d, &c) in cap.iter().enumerate() {
            if peak_dem[d] + self.peak(d) > c + EPS {
                sure = false;
                break;
            }
        }
        if sure {
            return true;
        }
        task.segments().iter().all(|seg| {
            let (lo, hi) = (seg.start as usize, seg.end as usize);
            cap.iter()
                .enumerate()
                .all(|(d, &c)| self.window_max(d, lo, hi) + seg.demand[d] <= c + EPS)
        })
    }

    /// Cosine similarity between the capacity-normalized demand and
    /// remaining-capacity vectors aggregated over the task span (paper
    /// section III, "Alternative Mapping and Fitting Policies"),
    /// recovered from window sums: for a window of length `L`,
    /// `Σ rem = (L·cap - Σu)/cap` and
    /// `Σ rem² = (L·cap² - 2·cap·Σu + Σu²)/cap²`.
    ///
    /// The seed's dense loop (kept verbatim as `DenseProfile`'s override)
    /// clamps per-slot remainders at zero; `fits` bounds usage to
    /// capacity + EPS, so on the feasible profiles the solvers actually
    /// build, clamping is inert and the two computations agree.
    fn similarity(&self, task: &Task) -> f64 {
        let cap = self.cap();
        let (mut dot, mut nrm_d, mut nrm_r) = (0.0f64, 0.0f64, 0.0f64);
        for (d, &c) in cap.iter().enumerate() {
            // one windowed-sum query per segment: the demand is constant
            // within a segment, so the per-slot cosine terms aggregate
            // exactly as in the flat derivation, window by window
            for seg in task.segments() {
                let (lo, hi) = (seg.start as usize, seg.end as usize);
                let len = (hi - lo + 1) as f64;
                let (sum, sumsq) = self.window_sums(d, lo, hi);
                let dem = seg.demand[d] / c;
                dot += dem * (len * c - sum) / c;
                nrm_d += dem * dem * len;
                nrm_r += (len * c * c - 2.0 * c * sum + sumsq) / (c * c);
            }
        }
        if nrm_d <= 0.0 || nrm_r <= 0.0 {
            return 0.0;
        }
        dot / (nrm_d.sqrt() * nrm_r.sqrt())
    }

    /// Peak load fraction over the busiest (t, d).
    fn peak_utilization(&self) -> f64 {
        let cap = self.cap();
        cap.iter()
            .enumerate()
            .map(|(d, &c)| self.peak(d) / c)
            .fold(0.0f64, f64::max)
    }

    /// Per-dimension peak usage over the whole timeline.
    fn peaks(&self) -> Vec<f64> {
        (0..self.dims()).map(|d| self.peak(d)).collect()
    }
}

// ---------------------------------------------------------------------------
// Indexed backend
// ---------------------------------------------------------------------------

/// Lazy segment trees for all D dimensions of one node, flattened into a
/// structure-of-arrays layout: five contiguous buffers, each holding D
/// dim-major blocks of `2·size` tree nodes (`size` for `lazy` — only
/// internal nodes carry pending adds). One [`LoadProfile`] used to own
/// `D` separate `SegTree`s at four `Vec`s each; a million-task solve
/// purchases tens of thousands of nodes, and 4·D allocations per node
/// was measurable churn. The blocks are contiguous per dimension, so a
/// range operation walks one cache-friendly slab.
///
/// Conventions (unchanged from the per-dimension trees, so every value —
/// and every FP operation order — is identical): aggregates stored at a
/// node are *true* subtree values (they already include the node's own
/// pending `lazy`); `lazy` is the uniform add not yet folded into the
/// children's aggregates. Queries are therefore immutable — they carry
/// the sum of ancestor lazies down the recursion instead of pushing —
/// and only `add` rebalances the arrays.
///
/// The `min` aggregate mirrors `max` under range-add. Padding leaves
/// (slots `n_slots..size`) never receive adds — `add` is always issued
/// with `r < n_slots`, so no applied subtree, and hence no pushed lazy,
/// ever covers them — and are pinned to +∞ at construction: the root min
/// is the floor over *real* slots only. (`max` needs no such pin: usage
/// is non-negative, so zero padding never wins a max.)
#[derive(Clone, Debug)]
struct SegStore {
    dims: usize,
    /// Leaves per dimension: the smallest power of two >= n_slots.
    size: usize,
    max: Vec<f64>,
    min: Vec<f64>,
    sum: Vec<f64>,
    sumsq: Vec<f64>,
    lazy: Vec<f64>,
}

impl SegStore {
    fn new(dims: usize, n_slots: usize) -> Self {
        let size = n_slots.next_power_of_two().max(1);
        let mut store = SegStore {
            dims,
            size,
            max: vec![0.0; dims * 2 * size],
            min: vec![0.0; dims * 2 * size],
            sum: vec![0.0; dims * 2 * size],
            sumsq: vec![0.0; dims * 2 * size],
            lazy: vec![0.0; dims * size],
        };
        if size > n_slots {
            for d in 0..dims {
                let base = d * 2 * size;
                for leaf in n_slots..size {
                    store.min[base + size + leaf] = f64::INFINITY;
                }
                for node in (1..size).rev() {
                    store.min[base + node] =
                        store.min[base + 2 * node].min(store.min[base + 2 * node + 1]);
                }
            }
        }
        store
    }

    /// Index of tree node `node` of dimension `d` in the aggregate buffers.
    #[inline]
    fn ix(&self, d: usize, node: usize) -> usize {
        d * 2 * self.size + node
    }

    /// Whole-timeline max of dimension `d` (root of its max block).
    #[inline]
    fn root_max(&self, d: usize) -> f64 {
        self.max[self.ix(d, 1)]
    }

    /// Whole-timeline floor of dimension `d` over real slots (root of its
    /// min block; padding is pinned to +∞ and cannot win).
    #[inline]
    fn root_min(&self, d: usize) -> f64 {
        self.min[self.ix(d, 1)]
    }

    /// Apply a uniform add of `c` over all `len` slots covered by `node`.
    /// Order matters: `sumsq` must read the pre-update `sum`.
    fn apply(&mut self, d: usize, node: usize, len: usize, c: f64) {
        let i = self.ix(d, node);
        let s = self.sum[i];
        self.sumsq[i] += 2.0 * c * s + c * c * len as f64;
        self.sum[i] = s + c * len as f64;
        self.max[i] += c;
        self.min[i] += c;
        if node < self.size {
            self.lazy[d * self.size + node] += c;
        }
    }

    fn push(&mut self, d: usize, node: usize, len: usize) {
        let c = self.lazy[d * self.size + node];
        // lint:allow(float-ord): exact-zero lazy tag — 0.0 means "no pending
        // update" for this segment-tree node; never a computed comparison.
        if c != 0.0 {
            self.apply(d, 2 * node, len / 2, c);
            self.apply(d, 2 * node + 1, len / 2, c);
            self.lazy[d * self.size + node] = 0.0;
        }
    }

    fn pull(&mut self, d: usize, node: usize) {
        let (i, l, r) = (self.ix(d, node), self.ix(d, 2 * node), self.ix(d, 2 * node + 1));
        self.max[i] = self.max[l].max(self.max[r]);
        self.min[i] = self.min[l].min(self.min[r]);
        self.sum[i] = self.sum[l] + self.sum[r];
        self.sumsq[i] = self.sumsq[l] + self.sumsq[r];
    }

    fn add(&mut self, d: usize, l: usize, r: usize, c: f64) {
        self.add_rec(d, 1, 0, self.size - 1, l, r, c);
    }

    #[allow(clippy::too_many_arguments)]
    fn add_rec(&mut self, d: usize, node: usize, lo: usize, hi: usize, l: usize, r: usize, c: f64) {
        if r < lo || hi < l {
            return;
        }
        if l <= lo && hi <= r {
            self.apply(d, node, hi - lo + 1, c);
            return;
        }
        self.push(d, node, hi - lo + 1);
        let mid = lo + (hi - lo) / 2;
        self.add_rec(d, 2 * node, lo, mid, l, r, c);
        self.add_rec(d, 2 * node + 1, mid + 1, hi, l, r, c);
        self.pull(d, node);
    }

    fn query_max(&self, d: usize, l: usize, r: usize) -> f64 {
        self.max_rec(d, 1, 0, self.size - 1, l, r, 0.0)
    }

    #[allow(clippy::too_many_arguments)]
    fn max_rec(
        &self,
        d: usize,
        node: usize,
        lo: usize,
        hi: usize,
        l: usize,
        r: usize,
        acc: f64,
    ) -> f64 {
        if r < lo || hi < l {
            return f64::NEG_INFINITY;
        }
        if l <= lo && hi <= r {
            return self.max[self.ix(d, node)] + acc;
        }
        let acc = acc + self.lazy[d * self.size + node];
        let mid = lo + (hi - lo) / 2;
        self.max_rec(d, 2 * node, lo, mid, l, r, acc)
            .max(self.max_rec(d, 2 * node + 1, mid + 1, hi, l, r, acc))
    }

    fn query_sums(&self, d: usize, l: usize, r: usize) -> (f64, f64) {
        self.sums_rec(d, 1, 0, self.size - 1, l, r, 0.0)
    }

    #[allow(clippy::too_many_arguments)]
    fn sums_rec(
        &self,
        d: usize,
        node: usize,
        lo: usize,
        hi: usize,
        l: usize,
        r: usize,
        acc: f64,
    ) -> (f64, f64) {
        if r < lo || hi < l {
            return (0.0, 0.0);
        }
        if l <= lo && hi <= r {
            let len = (hi - lo + 1) as f64;
            let i = self.ix(d, node);
            let s = self.sum[i];
            return (s + acc * len, self.sumsq[i] + 2.0 * acc * s + acc * acc * len);
        }
        let acc = acc + self.lazy[d * self.size + node];
        let mid = lo + (hi - lo) / 2;
        let (s1, q1) = self.sums_rec(d, 2 * node, lo, mid, l, r, acc);
        let (s2, q2) = self.sums_rec(d, 2 * node + 1, mid + 1, hi, l, r, acc);
        (s1 + s2, q1 + q2)
    }

    /// Collect ascending slots with value strictly above `threshold`.
    /// `n_slots` bounds the walk to real (non-padding) leaves.
    #[allow(clippy::too_many_arguments)]
    fn collect_over(
        &self,
        d: usize,
        node: usize,
        lo: usize,
        hi: usize,
        acc: f64,
        threshold: f64,
        n_slots: usize,
        out: &mut Vec<(usize, f64)>,
    ) {
        if lo >= n_slots || self.max[self.ix(d, node)] + acc <= threshold {
            return;
        }
        if lo == hi {
            // leaf: its sum over one slot is the slot's value
            out.push((lo, self.sum[self.ix(d, node)] + acc));
            return;
        }
        let acc = acc + self.lazy[d * self.size + node];
        let mid = lo + (hi - lo) / 2;
        self.collect_over(d, 2 * node, lo, mid, acc, threshold, n_slots, out);
        self.collect_over(d, 2 * node + 1, mid + 1, hi, acc, threshold, n_slots, out);
    }
}

/// Indexed load profile: all D lazy segment trees in one flattened
/// [`SegStore`]. All range operations are O(log T); whole-timeline peaks
/// and floors are O(1).
#[derive(Clone, Debug)]
pub struct LoadProfile {
    cap: Vec<f64>,
    n_slots: usize,
    store: SegStore,
}

impl LoadProfile {
    /// Minimum usage in dimension `d` over the whole (real) timeline —
    /// the floor the sure-reject in [`LoadProfile::fits`] tests against.
    /// O(1): the root of the min tree.
    pub fn floor(&self, d: usize) -> f64 {
        self.store.root_min(d)
    }
}

impl Profile for LoadProfile {
    const CHEAP_PEAKS: bool = true;

    fn new(n_slots: usize, cap: Vec<f64>) -> Self {
        assert!(n_slots > 0, "empty timeline");
        assert!(!cap.is_empty(), "empty capacity");
        let store = SegStore::new(cap.len(), n_slots);
        LoadProfile { cap, n_slots, store }
    }

    fn cap(&self) -> &[f64] {
        &self.cap
    }

    fn set_cap(&mut self, cap: Vec<f64>) {
        assert_eq!(cap.len(), self.cap.len(), "capacity dims changed");
        self.cap = cap;
    }

    fn range_add(&mut self, d: usize, lo: usize, hi: usize, c: f64) {
        // hard assert: the dense path panics on out-of-range slots via
        // indexing; the tree would silently clip instead, so keep the
        // same loud failure mode (O(1) next to the O(log T) update)
        assert!(
            lo <= hi && hi < self.n_slots,
            "range {lo}..={hi} outside timeline of {} slots",
            self.n_slots
        );
        self.store.add(d, lo, hi, c);
    }

    fn window_max(&self, d: usize, lo: usize, hi: usize) -> f64 {
        self.store.query_max(d, lo, hi)
    }

    fn window_sums(&self, d: usize, lo: usize, hi: usize) -> (f64, f64) {
        self.store.query_sums(d, lo, hi)
    }

    fn peak(&self, d: usize) -> f64 {
        // Padding leaves beyond n_slots hold zero usage; real usage is
        // non-negative, so the root max is the true timeline peak.
        self.store.root_max(d)
    }

    fn overloads(&self, d: usize, threshold: f64) -> Vec<(usize, f64)> {
        let mut out = Vec::new();
        self.store
            .collect_over(d, 1, 0, self.store.size - 1, 0.0, threshold, self.n_slots, &mut out);
        out
    }

    /// The trait's sure-accept plus a min-aggregate sure-*reject*, then
    /// the identical exact fallback. The reject is exact, never
    /// heuristic: every windowed max is >= the timeline floor and every
    /// segment demands at least the task's per-dimension minimum, so
    /// `floor + min-demand > cap` in any dimension implies every
    /// segment's exact check fails there too — the answer (`false`)
    /// matches the trait default and the dense reference bit-for-bit.
    /// This is what lets first-fit dismiss a full node in O(D) instead
    /// of O(S·D·log T) while scanning the prefix of loaded nodes.
    fn fits(&self, task: &Task) -> bool {
        let cap = self.cap();
        let peak_dem = task.peak();
        let mut sure = true;
        for (d, &c) in cap.iter().enumerate() {
            if peak_dem[d] + self.peak(d) > c + EPS {
                sure = false;
                break;
            }
        }
        if sure {
            return true;
        }
        let segs = task.segments();
        for (d, &c) in cap.iter().enumerate() {
            let floor = self.store.root_min(d);
            // peak >= every segment demand: cheap pre-test before the
            // per-segment min scan
            if floor + peak_dem[d] > c + EPS {
                let min_dem =
                    segs.iter().map(|s| s.demand[d]).fold(f64::INFINITY, f64::min);
                if floor + min_dem > c + EPS {
                    return false;
                }
            }
        }
        segs.iter().all(|seg| {
            let (lo, hi) = (seg.start as usize, seg.end as usize);
            cap.iter()
                .enumerate()
                .all(|(d, &c)| self.window_max(d, lo, hi) + seg.demand[d] <= c + EPS)
        })
    }
}

// ---------------------------------------------------------------------------
// Dense reference backend
// ---------------------------------------------------------------------------

/// Dense reference profile: the seed's per-(t, d) usage array with its
/// exact t-major update and scan order. O(span·D) updates, O(T·D) peaks.
/// Kept as the property-test reference and the benchmark baseline.
#[derive(Clone, Debug)]
pub struct DenseProfile {
    cap: Vec<f64>,
    n_slots: usize,
    /// usage[t * dims + d]
    usage: Vec<f64>,
}

impl Profile for DenseProfile {
    fn new(n_slots: usize, cap: Vec<f64>) -> Self {
        assert!(n_slots > 0, "empty timeline");
        assert!(!cap.is_empty(), "empty capacity");
        DenseProfile { usage: vec![0.0; n_slots * cap.len()], cap, n_slots }
    }

    fn cap(&self) -> &[f64] {
        &self.cap
    }

    fn set_cap(&mut self, cap: Vec<f64>) {
        assert_eq!(cap.len(), self.cap.len(), "capacity dims changed");
        self.cap = cap;
    }

    fn range_add(&mut self, d: usize, lo: usize, hi: usize, c: f64) {
        let dims = self.cap.len();
        for t in lo..=hi {
            self.usage[t * dims + d] += c;
        }
    }

    fn window_max(&self, d: usize, lo: usize, hi: usize) -> f64 {
        let dims = self.cap.len();
        (lo..=hi)
            .map(|t| self.usage[t * dims + d])
            .fold(f64::NEG_INFINITY, f64::max)
    }

    fn window_sums(&self, d: usize, lo: usize, hi: usize) -> (f64, f64) {
        let dims = self.cap.len();
        let (mut s, mut q) = (0.0f64, 0.0f64);
        for t in lo..=hi {
            let v = self.usage[t * dims + d];
            s += v;
            q += v * v;
        }
        (s, q)
    }

    fn peak(&self, d: usize) -> f64 {
        self.window_max(d, 0, self.n_slots - 1)
    }

    fn overloads(&self, d: usize, threshold: f64) -> Vec<(usize, f64)> {
        let dims = self.cap.len();
        (0..self.n_slots)
            .filter_map(|t| {
                let v = self.usage[t * dims + d];
                (v > threshold).then_some((t, v))
            })
            .collect()
    }

    /// Seed-faithful dense feasibility scan: t-major within each segment,
    /// per-slot compare, no peak fast path (computing the peak would
    /// itself cost O(T·D)).
    fn fits(&self, task: &Task) -> bool {
        let dims = self.cap.len();
        for seg in task.segments() {
            for t in seg.start as usize..=seg.end as usize {
                let base = t * dims;
                for (d, &c) in self.cap.iter().enumerate() {
                    if self.usage[base + d] + seg.demand[d] > c + EPS {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Seed-faithful per-slot cosine loop with remainders clamped at
    /// zero — the reference the indexed sum/sumsq derivation is
    /// property-tested against. The two agree exactly on feasible
    /// profiles (clamping can only trigger on slots loaded past capacity,
    /// which `fits` bounds to the EPS tolerance).
    fn similarity(&self, task: &Task) -> f64 {
        let dims = self.cap.len();
        let (mut dot, mut nrm_d, mut nrm_r) = (0.0f64, 0.0f64, 0.0f64);
        for seg in task.segments() {
            for t in seg.start as usize..=seg.end as usize {
                let base = t * dims;
                for (d, &c) in self.cap.iter().enumerate() {
                    let dem = seg.demand[d] / c;
                    let rem = (c - self.usage[base + d]).max(0.0) / c;
                    dot += dem * rem;
                    nrm_d += dem * dem;
                    nrm_r += rem * rem;
                }
            }
        }
        if nrm_d <= 0.0 || nrm_r <= 0.0 {
            return 0.0;
        }
        dot / (nrm_d.sqrt() * nrm_r.sqrt())
    }

    /// Dense add in the seed's t-major order (FP-faithful).
    fn add_task(&mut self, task: &Task) {
        let dims = self.cap.len();
        for seg in task.segments() {
            for t in seg.start as usize..=seg.end as usize {
                let base = t * dims;
                for d in 0..dims {
                    self.usage[base + d] += seg.demand[d];
                }
            }
        }
    }

    fn remove_task(&mut self, task: &Task) {
        let dims = self.cap.len();
        for seg in task.segments() {
            for t in seg.start as usize..=seg.end as usize {
                let base = t * dims;
                for d in 0..dims {
                    self.usage[base + d] -= seg.demand[d];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(demand: Vec<f64>, start: u32, end: u32) -> Task {
        Task::new(0, demand, start, end)
    }

    #[test]
    fn segstore_matches_brute_force() {
        // deterministic mixed add/query workload against flat arrays, on
        // a two-dimension store so the dim-major blocks are exercised
        let n = 37usize; // deliberately not a power of two
        let mut store = SegStore::new(2, n);
        let mut flat = [vec![0.0f64; n], vec![0.0f64; n]];
        let ops: [(usize, usize, usize, f64); 8] = [
            (0, 0, 36, 0.25),
            (1, 3, 11, 1.5),
            (0, 11, 11, -0.5),
            (1, 20, 30, 0.125),
            (0, 0, 5, 2.0),
            (1, 30, 36, 0.75),
            (0, 5, 25, -0.125),
            (1, 0, 36, 0.0625),
        ];
        for &(d, l, r, c) in &ops {
            store.add(d, l, r, c);
            for t in l..=r {
                flat[d][t] += c;
            }
            for dim in 0..2 {
                for &(ql, qr) in &[(0usize, n - 1), (2, 9), (10, 20), (25, 36), (7, 7)] {
                    let want_max =
                        flat[dim][ql..=qr].iter().copied().fold(f64::NEG_INFINITY, f64::max);
                    let want_sum: f64 = flat[dim][ql..=qr].iter().sum();
                    let want_sq: f64 = flat[dim][ql..=qr].iter().map(|v| v * v).sum();
                    assert!(
                        (store.query_max(dim, ql, qr) - want_max).abs() < 1e-12,
                        "max d{dim} {ql}..={qr}"
                    );
                    let (s, q) = store.query_sums(dim, ql, qr);
                    assert!((s - want_sum).abs() < 1e-9, "sum d{dim} {ql}..={qr}");
                    assert!((q - want_sq).abs() < 1e-9, "sumsq d{dim} {ql}..={qr}");
                }
                // roots are the whole-array peak and floor, per dimension
                // (padding pinned to +inf cannot win the min)
                let peak = flat[dim].iter().copied().fold(f64::NEG_INFINITY, f64::max);
                let floor = flat[dim].iter().copied().fold(f64::INFINITY, f64::min);
                assert!((store.root_max(dim) - peak).abs() < 1e-12);
                assert!((store.root_min(dim) - floor).abs() < 1e-9, "floor d{dim}");
            }
        }
    }

    #[test]
    fn segstore_overload_enumeration() {
        let n = 10usize;
        let mut store = SegStore::new(1, n);
        store.add(0, 2, 5, 1.0);
        store.add(0, 4, 8, 1.0);
        let mut out = Vec::new();
        store.collect_over(0, 1, 0, store.size - 1, 0.0, 1.5, n, &mut out);
        let slots: Vec<usize> = out.iter().map(|&(t, _)| t).collect();
        assert_eq!(slots, vec![4, 5]);
        for &(_, v) in &out {
            assert!((v - 2.0).abs() < 1e-12);
        }
        // threshold above the peak: nothing
        out.clear();
        store.collect_over(0, 1, 0, store.size - 1, 0.0, 2.5, n, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn floor_tracks_timeline_min_over_real_slots() {
        // 6 slots in an 8-leaf tree: the two padding leaves must never
        // drag the root min to zero
        let mut p: LoadProfile = Profile::new(6, vec![1.0]);
        p.add_task(&task(vec![0.4], 0, 5));
        assert!((p.floor(0) - 0.4).abs() < 1e-12);
        p.add_task(&task(vec![0.3], 2, 4));
        assert!((p.floor(0) - 0.4).abs() < 1e-12, "quietest slot still 0.4");
        p.add_task(&task(vec![0.2], 0, 1));
        p.add_task(&task(vec![0.2], 5, 5));
        assert!((p.floor(0) - 0.6).abs() < 1e-12);
        p.remove_task(&task(vec![0.4], 0, 5));
        assert!((p.floor(0) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn fits_sure_reject_agrees_with_dense() {
        // the node is uniformly loaded to 0.8: the floor alone rejects a
        // 0.3-task anywhere; the dense reference must agree, and a probe
        // the floor cannot reject must still pass the exact path
        let cap = vec![1.0, 1.0];
        let mut idx: LoadProfile = Profile::new(9, cap.clone());
        let mut dense: DenseProfile = Profile::new(9, cap.clone());
        let heavy = task(vec![0.8, 0.1], 0, 8);
        idx.add_task(&heavy);
        dense.add_task(&heavy);
        let probe = task(vec![0.3, 0.3], 2, 6);
        assert!(!idx.fits(&probe));
        assert_eq!(idx.fits(&probe), dense.fits(&probe));
        let ok = task(vec![0.15, 0.3], 2, 6);
        assert!(idx.fits(&ok));
        assert_eq!(idx.fits(&ok), dense.fits(&ok));
        // shaped probe: only the quietest segment matters for the reject
        use crate::model::task::DemandSeg;
        let shaped = Task::piecewise(
            1,
            vec![
                DemandSeg { start: 1, end: 3, demand: vec![0.5, 0.1] },
                DemandSeg { start: 4, end: 6, demand: vec![0.1, 0.1] },
            ],
        );
        assert!(!idx.fits(&shaped), "first segment overflows dim 0");
        assert_eq!(idx.fits(&shaped), dense.fits(&shaped));
    }

    #[test]
    fn profiles_agree_on_scripted_ops() {
        let cap = vec![1.0, 0.5];
        let mut idx: LoadProfile = Profile::new(12, cap.clone());
        let mut dense: DenseProfile = Profile::new(12, cap.clone());
        let tasks = [
            task(vec![0.3, 0.1], 0, 7),
            task(vec![0.4, 0.2], 2, 4),
            task(vec![0.2, 0.15], 4, 11),
        ];
        for t in &tasks {
            idx.add_task(t);
            dense.add_task(t);
        }
        let probe = task(vec![0.35, 0.2], 3, 6);
        assert_eq!(idx.fits(&probe), dense.fits(&probe));
        assert!((idx.similarity(&probe) - dense.similarity(&probe)).abs() < 1e-12);
        for d in 0..2 {
            assert!((idx.peak(d) - dense.peak(d)).abs() < 1e-12);
            assert!((idx.window_max(d, 3, 6) - dense.window_max(d, 3, 6)).abs() < 1e-12);
            let (s1, q1) = idx.window_sums(d, 2, 9);
            let (s2, q2) = dense.window_sums(d, 2, 9);
            assert!((s1 - s2).abs() < 1e-12 && (q1 - q2).abs() < 1e-12);
        }
        idx.remove_task(&tasks[1]);
        dense.remove_task(&tasks[1]);
        assert!((idx.peak(0) - dense.peak(0)).abs() < 1e-12);
        assert_eq!(idx.fits(&probe), dense.fits(&probe));
    }

    #[test]
    fn fits_fast_path_and_exact_path_agree() {
        // a profile busy outside the probe window: the fast accept fails
        // (timeline peak too high) but the windowed check must admit
        let mut p: LoadProfile = Profile::new(16, vec![1.0]);
        p.add_task(&task(vec![0.9], 0, 3));
        let probe = task(vec![0.8], 8, 12);
        assert!(p.fits(&probe));
        // and inside the busy window it must reject
        let clash = task(vec![0.2], 1, 2);
        assert!(!p.fits(&clash));
        // fast accept: empty window everywhere
        let tiny = task(vec![0.05], 0, 15);
        assert!(p.fits(&tiny));
    }

    #[test]
    fn similarity_matches_seed_dense_loop() {
        // recompute the seed's per-slot cosine loop by hand and compare
        let cap = vec![1.0, 0.8];
        let mut p: LoadProfile = Profile::new(8, cap.clone());
        let held = task(vec![0.5, 0.1], 1, 5);
        p.add_task(&held);
        let probe = task(vec![0.2, 0.4], 0, 6);
        let mut usage = vec![0.0f64; 8 * 2];
        for t in 1..=5usize {
            usage[t * 2] += 0.5;
            usage[t * 2 + 1] += 0.1;
        }
        let (mut dot, mut nd, mut nr) = (0.0f64, 0.0f64, 0.0f64);
        for t in 0..=6usize {
            for d in 0..2 {
                let dem = probe.peak()[d] / cap[d];
                let rem = (cap[d] - usage[t * 2 + d]).max(0.0) / cap[d];
                dot += dem * rem;
                nd += dem * dem;
                nr += rem * rem;
            }
        }
        let want = dot / (nd.sqrt() * nr.sqrt());
        assert!((p.similarity(&probe) - want).abs() < 1e-12);
    }

    #[test]
    fn set_cap_rescales_feasibility() {
        let mut p: LoadProfile = Profile::new(4, vec![0.5]);
        p.add_task(&task(vec![0.4], 0, 3));
        assert!(!p.fits(&task(vec![0.3], 1, 2)));
        p.set_cap(vec![1.0]);
        assert!(p.fits(&task(vec![0.3], 1, 2)));
        assert!((p.peak_utilization() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn piecewise_task_matches_flat_split() {
        use crate::model::task::DemandSeg;
        // a shaped task must load the profile exactly like the equivalent
        // set of flat per-segment tasks, on both backends
        let shaped = Task::piecewise(
            0,
            vec![
                DemandSeg { start: 1, end: 3, demand: vec![0.2, 0.5] },
                DemandSeg { start: 4, end: 6, demand: vec![0.7, 0.1] },
            ],
        );
        let split = [
            task(vec![0.2, 0.5], 1, 3),
            task(vec![0.7, 0.1], 4, 6),
        ];
        let cap = vec![1.0, 1.0];
        let mut a: LoadProfile = Profile::new(8, cap.clone());
        let mut b: LoadProfile = Profile::new(8, cap.clone());
        let mut d: DenseProfile = Profile::new(8, cap.clone());
        a.add_task(&shaped);
        d.add_task(&shaped);
        for t in &split {
            b.add_task(t);
        }
        for dim in 0..2 {
            for t in 0..8 {
                let (sa, _) = a.window_sums(dim, t, t);
                let (sb, _) = b.window_sums(dim, t, t);
                let (sd, _) = d.window_sums(dim, t, t);
                assert!((sa - sb).abs() < 1e-12, "dim {dim} slot {t}");
                assert!((sa - sd).abs() < 1e-12, "dim {dim} slot {t}");
            }
        }
        // per-segment feasibility: a probe clashing only with the second
        // window is rejected, one fitting beside the peak is accepted
        assert!(!a.fits(&task(vec![0.4, 0.4], 4, 5)));
        assert!(a.fits(&task(vec![0.4, 0.4], 1, 3)));
        // shaped probe against a loaded profile: fits iff every segment fits
        let probe = Task::piecewise(
            1,
            vec![
                DemandSeg { start: 1, end: 3, demand: vec![0.7, 0.4] },
                DemandSeg { start: 4, end: 6, demand: vec![0.2, 0.4] },
            ],
        );
        assert!(a.fits(&probe));
        assert_eq!(a.fits(&probe), d.fits(&probe));
        let clash = Task::piecewise(
            2,
            vec![
                DemandSeg { start: 1, end: 3, demand: vec![0.7, 0.4] },
                DemandSeg { start: 4, end: 6, demand: vec![0.4, 0.4] },
            ],
        );
        assert!(!a.fits(&clash));
        assert_eq!(a.fits(&clash), d.fits(&clash));
        // similarity agrees across backends on shaped probes too
        assert!((a.similarity(&probe) - d.similarity(&probe)).abs() < 1e-9);
        // remove restores the empty profile
        a.remove_task(&shaped);
        assert!(a.peak(0).abs() < 1e-12 && a.peak(1).abs() < 1e-12);
    }

    #[test]
    fn single_slot_timeline() {
        let mut p: LoadProfile = Profile::new(1, vec![1.0]);
        p.add_task(&task(vec![0.6], 0, 0));
        assert!((p.peak(0) - 0.6).abs() < 1e-12);
        assert!((p.floor(0) - 0.6).abs() < 1e-12);
        assert!(p.fits(&task(vec![0.4], 0, 0)));
        assert!(!p.fits(&task(vec![0.5], 0, 0)));
        assert_eq!(p.overloads(0, 0.5).len(), 1);
    }
}
