//! Problem model: tasks, node-types, instances, timelines, solutions, costs.

pub mod cost;
pub mod instance;
pub mod nodetype;
pub mod solution;
pub mod task;
pub mod timeline;

pub use cost::CostModel;
pub use instance::Instance;
pub use nodetype::NodeType;
pub use solution::{PlacedNode, Solution, Violation};
pub use task::Task;
pub use timeline::{trim, Trimmed};
