//! Problem model: tasks, node-types, instances, timelines, solutions,
//! costs, and the shared load-profile subsystem.

pub mod cost;
pub mod delta;
pub mod instance;
pub mod load;
pub mod nodetype;
pub mod solution;
pub mod task;
pub mod timeline;

/// Feasibility tolerance shared by placement, local search, the exact
/// solver and `Solution::verify` — one constant so the solvers and the
/// verifier can never disagree on what "fits".
pub const EPS: f64 = 1e-9;

pub use cost::CostModel;
pub use delta::Delta;
pub use instance::Instance;
pub use load::{DenseProfile, LoadProfile, Profile};
pub use nodetype::NodeType;
pub use solution::{PlacedNode, Solution, Violation};
pub use task::{DemandProfile, DemandSeg, Task};
pub use timeline::{trim, Trimmed};
