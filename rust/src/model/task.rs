//! Tasks: D-dimensional resource demands over a closed timeslot interval.

/// A time-limited task (paper section II): demand vector `dem(u,d)` and an
/// inclusive active span `[start, end]` in discrete timeslots.
#[derive(Clone, Debug, PartialEq)]
pub struct Task {
    /// Stable external identifier (index into the source trace).
    pub id: u64,
    /// Demand along each of the D dimensions, normalized to [0, 1].
    pub demand: Vec<f64>,
    /// First active timeslot (0-based).
    pub start: u32,
    /// Last active timeslot, inclusive; `end >= start`.
    pub end: u32,
}

impl Task {
    pub fn new(id: u64, demand: Vec<f64>, start: u32, end: u32) -> Self {
        assert!(end >= start, "task {id}: end {end} < start {start}");
        assert!(!demand.is_empty(), "task {id}: empty demand");
        Task { id, demand, start, end }
    }

    /// Number of resource dimensions.
    pub fn dims(&self) -> usize {
        self.demand.len()
    }

    /// Is the task active at timeslot `t` (paper: `u ~ t`)?
    #[inline]
    pub fn active_at(&self, t: u32) -> bool {
        t >= self.start && t <= self.end
    }

    /// Number of active timeslots.
    pub fn span_len(&self) -> u32 {
        self.end - self.start + 1
    }

    /// Do the active spans of two tasks intersect?
    pub fn overlaps(&self, other: &Task) -> bool {
        self.start <= other.end && other.start <= self.end
    }

    /// A task is *small* w.r.t. a capacity vector if every demand component
    /// is at most half the capacity (paper section III analysis).
    pub fn is_small_for(&self, capacity: &[f64]) -> bool {
        self.demand.iter().zip(capacity).all(|(&d, &c)| d <= c / 2.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: u32, e: u32) -> Task {
        Task::new(0, vec![0.1], s, e)
    }

    #[test]
    fn active_span() {
        let u = t(2, 5);
        assert!(!u.active_at(1));
        assert!(u.active_at(2));
        assert!(u.active_at(5));
        assert!(!u.active_at(6));
        assert_eq!(u.span_len(), 4);
    }

    #[test]
    fn overlap_cases() {
        assert!(t(0, 3).overlaps(&t(3, 5)));
        assert!(t(3, 5).overlaps(&t(0, 3)));
        assert!(!t(0, 2).overlaps(&t(3, 5)));
        assert!(t(0, 9).overlaps(&t(4, 5)));
    }

    #[test]
    #[should_panic]
    fn bad_interval_rejected() {
        Task::new(1, vec![0.1], 5, 4);
    }

    #[test]
    fn smallness() {
        let u = Task::new(0, vec![0.3, 0.1], 0, 0);
        assert!(u.is_small_for(&[0.6, 0.2]));
        assert!(!u.is_small_for(&[0.5, 0.2]));
    }
}
