//! Tasks: D-dimensional resource demands over a closed timeslot interval,
//! generalized from a constant demand vector to a piecewise-constant
//! [`DemandProfile`].
//!
//! The paper's core motivation is that real tasks "may be active only
//! during specific time-periods or may have *dynamic load profiles*": a
//! diurnal service needs its peak capacity only during business hours, a
//! ramping batch job grows as it fans out. Modeling that load shape as a
//! first-class profile — ordered segments `(window, demand)` covering the
//! task's span — lets the optimizer reuse the same node for two tasks
//! whose *peaks* never coincide, where a constant-demand model would have
//! to reserve both peaks simultaneously (or fake the shape by splitting
//! the task into many flat tasks, inflating n and hiding the reuse from
//! per-task mapping).
//!
//! The flat case is exactly one segment spanning `[start, end]` and is
//! represented canonically (a single-segment piecewise construction
//! normalizes to it), so every pre-profile code path — placement, LP,
//! verification — remains bit-identical on constant-demand instances.
//!
//! Aggregates the solver stack uses:
//!   * [`Task::peak`] — per-dimension maximum demand; drives
//!     admissibility ([`crate::model::NodeType::admits`]), smallness and
//!     the `h_max` penalty,
//!   * [`Task::avg`] — per-dimension time-averaged demand; drives the
//!     `h_avg` penalty (the time-weighted generalization of the paper's
//!     relative demand),
//!   * [`Task::demand_at`] — the exact demand at one timeslot; drives
//!     per-slot feasibility (load profiles, `Solution::verify`, the
//!     mapping LP's congestion rows).

/// One piecewise-constant window of demand: `demand` holds over the
/// inclusive timeslot interval `[start, end]`.
#[derive(Clone, Debug, PartialEq)]
pub struct DemandSeg {
    /// First timeslot of the window (inclusive).
    pub start: u32,
    /// Last timeslot of the window (inclusive); `end >= start`.
    pub end: u32,
    /// Demand along each dimension over the window, normalized to [0, 1].
    pub demand: Vec<f64>,
}

/// A piecewise-constant demand profile: ordered, contiguous segments.
/// Invariants (enforced by [`DemandProfile::new`]):
///   * at least one segment, every demand vector non-empty and of one
///     shared dimensionality,
///   * each window is a valid inclusive interval,
///   * consecutive windows are contiguous
///     (`segs[i+1].start == segs[i].end + 1`).
#[derive(Clone, Debug, PartialEq)]
pub struct DemandProfile {
    segs: Vec<DemandSeg>,
}

impl DemandProfile {
    /// Constant demand over `[start, end]` — the seed model's task shape.
    pub fn flat(demand: Vec<f64>, start: u32, end: u32) -> DemandProfile {
        assert!(end >= start, "flat profile: end {end} < start {start}");
        assert!(!demand.is_empty(), "flat profile: empty demand");
        DemandProfile { segs: vec![DemandSeg { start, end, demand }] }
    }

    /// Validate and build a piecewise profile. Errors (not panics) so
    /// loaders can reject malformed external data gracefully.
    pub fn new(segs: Vec<DemandSeg>) -> Result<DemandProfile, String> {
        let Some(first) = segs.first() else {
            return Err("profile has no segments".into());
        };
        let dims = first.demand.len();
        if dims == 0 {
            return Err("profile segment has an empty demand".into());
        }
        for (i, seg) in segs.iter().enumerate() {
            if seg.end < seg.start {
                return Err(format!(
                    "segment {i}: end {} < start {}",
                    seg.end, seg.start
                ));
            }
            if seg.demand.len() != dims {
                return Err(format!(
                    "segment {i}: {} dims, expected {dims}",
                    seg.demand.len()
                ));
            }
            if i > 0 {
                let prev_end = segs[i - 1].end;
                if seg.start != prev_end + 1 {
                    return Err(format!(
                        "segment {i} starts at {} but the previous window ends at \
                         {prev_end} (segments must be contiguous)",
                        seg.start
                    ));
                }
            }
        }
        Ok(DemandProfile { segs })
    }

    /// The ordered segments covering `[start(), end()]`.
    pub fn segments(&self) -> &[DemandSeg] {
        &self.segs
    }

    /// First active timeslot.
    pub fn start(&self) -> u32 {
        self.segs[0].start
    }

    /// Last active timeslot (inclusive).
    pub fn end(&self) -> u32 {
        self.segs[self.segs.len() - 1].end
    }

    pub fn dims(&self) -> usize {
        self.segs[0].demand.len()
    }

    /// One segment — the constant-demand case every seed path handles.
    pub fn is_flat(&self) -> bool {
        self.segs.len() == 1
    }

    /// Demand vector at timeslot `t`, `None` when inactive.
    pub fn demand_at(&self, t: u32) -> Option<&[f64]> {
        // segments are ordered by start; find the window containing t
        let i = match self.segs.binary_search_by(|s| s.start.cmp(&t)) {
            Ok(i) => i,
            Err(0) => return None,
            Err(i) => i - 1,
        };
        let seg = &self.segs[i];
        (t <= seg.end).then(|| seg.demand.as_slice())
    }

    /// Per-dimension maximum demand over the whole span.
    pub fn peak_vec(&self) -> Vec<f64> {
        let mut peak = self.segs[0].demand.clone();
        for seg in &self.segs[1..] {
            for (p, &x) in peak.iter_mut().zip(&seg.demand) {
                *p = p.max(x);
            }
        }
        peak
    }

    /// Per-dimension time-averaged demand (window-length weighted).
    pub fn avg_vec(&self) -> Vec<f64> {
        let dims = self.dims();
        let mut acc = vec![0.0f64; dims];
        let mut total = 0.0f64;
        for seg in &self.segs {
            let len = (seg.end - seg.start + 1) as f64;
            total += len;
            for (a, &x) in acc.iter_mut().zip(&seg.demand) {
                *a += x * len;
            }
        }
        for a in acc.iter_mut() {
            *a /= total;
        }
        acc
    }
}

/// A time-limited task (paper section II): a demand profile over an
/// inclusive active span `[start, end]` in discrete timeslots. Construct
/// flat tasks with [`Task::new`] (the seed signature) and shaped tasks
/// with [`Task::piecewise`] / [`Task::try_piecewise`].
#[derive(Clone, Debug, PartialEq)]
pub struct Task {
    /// Stable external identifier (index into the source trace).
    pub id: u64,
    /// First active timeslot (0-based).
    pub start: u32,
    /// Last active timeslot, inclusive; `end >= start`.
    pub end: u32,
    /// Piecewise-constant demand covering exactly `[start, end]`.
    profile: DemandProfile,
    /// Cached per-dimension peak; empty for flat tasks (the single
    /// segment's demand *is* the peak — no second allocation).
    peak: Vec<f64>,
    /// Cached per-dimension time-averaged demand; empty for flat tasks.
    avg: Vec<f64>,
}

impl Task {
    /// Constant demand over `[start, end]` — same signature and panics as
    /// the pre-profile model, so every generator and test constructs flat
    /// tasks unchanged.
    pub fn new(id: u64, demand: Vec<f64>, start: u32, end: u32) -> Self {
        assert!(end >= start, "task {id}: end {end} < start {start}");
        assert!(!demand.is_empty(), "task {id}: empty demand");
        Task {
            id,
            start,
            end,
            profile: DemandProfile::flat(demand, start, end),
            peak: Vec::new(),
            avg: Vec::new(),
        }
    }

    /// Build a shaped task from a validated profile. A single-segment
    /// profile normalizes to the flat representation, so "piecewise with
    /// one segment" and "flat" are the same value (bit-identical
    /// downstream).
    pub fn from_profile(id: u64, profile: DemandProfile) -> Self {
        let (start, end) = (profile.start(), profile.end());
        let (peak, avg) = if profile.is_flat() {
            (Vec::new(), Vec::new())
        } else {
            (profile.peak_vec(), profile.avg_vec())
        };
        Task { id, start, end, profile, peak, avg }
    }

    /// Validate segments and build a shaped task; errors on malformed
    /// external data instead of panicking.
    pub fn try_piecewise(id: u64, segs: Vec<DemandSeg>) -> Result<Self, String> {
        let profile = DemandProfile::new(segs).map_err(|e| format!("task {id}: {e}"))?;
        Ok(Task::from_profile(id, profile))
    }

    /// [`Task::try_piecewise`] for programmatic construction: panics on
    /// invalid segments (programmer error, like [`Task::new`]).
    pub fn piecewise(id: u64, segs: Vec<DemandSeg>) -> Self {
        Task::try_piecewise(id, segs).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Same task under a new id (trace re-labeling: scenario sampling,
    /// sub-instances, surprise-load streams).
    pub fn with_id(&self, id: u64) -> Self {
        Task { id, ..self.clone() }
    }

    /// The piecewise-constant demand segments (one for flat tasks).
    pub fn segments(&self) -> &[DemandSeg] {
        self.profile.segments()
    }

    /// Constant-demand task (single segment)?
    pub fn is_flat(&self) -> bool {
        self.profile.is_flat()
    }

    /// Per-dimension *peak* demand: the vector admissibility, smallness
    /// and `h_max` reason about. For flat tasks this is the demand itself.
    pub fn peak(&self) -> &[f64] {
        if self.peak.is_empty() {
            &self.profile.segments()[0].demand
        } else {
            &self.peak
        }
    }

    /// Per-dimension *time-averaged* demand: the `h_avg` aggregate. For
    /// flat tasks this is the demand itself (exactly — no re-derivation).
    pub fn avg(&self) -> &[f64] {
        if self.avg.is_empty() {
            &self.profile.segments()[0].demand
        } else {
            &self.avg
        }
    }

    /// Demand vector at timeslot `t`, `None` when the task is inactive.
    pub fn demand_at(&self, t: u32) -> Option<&[f64]> {
        self.profile.demand_at(t)
    }

    /// Clamp every segment's demand component to `cap` (generators use
    /// this to keep drawn demands admissible on the anchor node-type).
    pub fn clamp_demand(&mut self, cap: &[f64]) {
        for seg in self.profile.segs.iter_mut() {
            for (x, &c) in seg.demand.iter_mut().zip(cap) {
                *x = x.min(c);
            }
        }
        if !self.peak.is_empty() {
            self.peak = self.profile.peak_vec();
            self.avg = self.profile.avg_vec();
        }
    }

    /// Number of resource dimensions.
    pub fn dims(&self) -> usize {
        self.profile.dims()
    }

    /// Is the task active at timeslot `t` (paper: `u ~ t`)?
    #[inline]
    pub fn active_at(&self, t: u32) -> bool {
        t >= self.start && t <= self.end
    }

    /// Number of active timeslots.
    pub fn span_len(&self) -> u32 {
        self.end - self.start + 1
    }

    /// Do the active spans of two tasks intersect?
    pub fn overlaps(&self, other: &Task) -> bool {
        self.start <= other.end && other.start <= self.end
    }

    /// A task is *small* w.r.t. a capacity vector if every *peak* demand
    /// component is at most half the capacity (paper section III
    /// analysis; a shaped task never exceeds its peak, so the bin-packing
    /// argument carries over).
    pub fn is_small_for(&self, capacity: &[f64]) -> bool {
        self.peak().iter().zip(capacity).all(|(&d, &c)| d <= c / 2.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: u32, e: u32) -> Task {
        Task::new(0, vec![0.1], s, e)
    }

    fn shaped() -> Task {
        Task::piecewise(
            7,
            vec![
                DemandSeg { start: 2, end: 3, demand: vec![0.2, 0.1] },
                DemandSeg { start: 4, end: 7, demand: vec![0.6, 0.3] },
                DemandSeg { start: 8, end: 9, demand: vec![0.1, 0.4] },
            ],
        )
    }

    #[test]
    fn active_span() {
        let u = t(2, 5);
        assert!(!u.active_at(1));
        assert!(u.active_at(2));
        assert!(u.active_at(5));
        assert!(!u.active_at(6));
        assert_eq!(u.span_len(), 4);
    }

    #[test]
    fn overlap_cases() {
        assert!(t(0, 3).overlaps(&t(3, 5)));
        assert!(t(3, 5).overlaps(&t(0, 3)));
        assert!(!t(0, 2).overlaps(&t(3, 5)));
        assert!(t(0, 9).overlaps(&t(4, 5)));
    }

    #[test]
    #[should_panic]
    fn bad_interval_rejected() {
        Task::new(1, vec![0.1], 5, 4);
    }

    #[test]
    fn smallness() {
        let u = Task::new(0, vec![0.3, 0.1], 0, 0);
        assert!(u.is_small_for(&[0.6, 0.2]));
        assert!(!u.is_small_for(&[0.5, 0.2]));
        // shaped: smallness is a peak property
        let s = shaped();
        assert!(s.is_small_for(&[1.2, 0.8]));
        assert!(!s.is_small_for(&[1.1, 0.7]));
    }

    #[test]
    fn flat_task_is_single_segment_with_shared_aggregates() {
        let u = Task::new(3, vec![0.25, 0.5], 1, 4);
        assert!(u.is_flat());
        assert_eq!(u.segments().len(), 1);
        assert_eq!(u.peak(), &[0.25, 0.5]);
        assert_eq!(u.avg(), &[0.25, 0.5]);
        assert_eq!(u.demand_at(1), Some(&[0.25, 0.5][..]));
        assert_eq!(u.demand_at(0), None);
        assert_eq!(u.demand_at(5), None);
    }

    #[test]
    fn single_segment_piecewise_normalizes_to_flat() {
        let flat = Task::new(5, vec![0.2, 0.3], 2, 6);
        let pw = Task::piecewise(
            5,
            vec![DemandSeg { start: 2, end: 6, demand: vec![0.2, 0.3] }],
        );
        assert_eq!(flat, pw);
        assert!(pw.is_flat());
    }

    #[test]
    fn shaped_span_and_aggregates() {
        let s = shaped();
        assert_eq!((s.start, s.end), (2, 9));
        assert_eq!(s.span_len(), 8);
        assert!(!s.is_flat());
        assert_eq!(s.peak(), &[0.6, 0.4]);
        // avg: (0.2*2 + 0.6*4 + 0.1*2)/8 = 0.375; (0.1*2 + 0.3*4 + 0.4*2)/8 = 0.275
        assert!((s.avg()[0] - 0.375).abs() < 1e-12);
        assert!((s.avg()[1] - 0.275).abs() < 1e-12);
        assert_eq!(s.demand_at(3), Some(&[0.2, 0.1][..]));
        assert_eq!(s.demand_at(4), Some(&[0.6, 0.3][..]));
        assert_eq!(s.demand_at(9), Some(&[0.1, 0.4][..]));
        assert_eq!(s.demand_at(1), None);
        assert_eq!(s.demand_at(10), None);
    }

    #[test]
    fn malformed_profiles_are_errors() {
        // gap between windows
        let err = Task::try_piecewise(
            1,
            vec![
                DemandSeg { start: 0, end: 1, demand: vec![0.1] },
                DemandSeg { start: 3, end: 4, demand: vec![0.1] },
            ],
        )
        .unwrap_err();
        assert!(err.contains("contiguous"), "{err}");
        // inverted window
        assert!(Task::try_piecewise(
            1,
            vec![DemandSeg { start: 5, end: 4, demand: vec![0.1] }],
        )
        .is_err());
        // dims mismatch
        assert!(Task::try_piecewise(
            1,
            vec![
                DemandSeg { start: 0, end: 1, demand: vec![0.1, 0.2] },
                DemandSeg { start: 2, end: 3, demand: vec![0.1] },
            ],
        )
        .is_err());
        // empty
        assert!(Task::try_piecewise(1, vec![]).is_err());
        assert!(Task::try_piecewise(
            1,
            vec![DemandSeg { start: 0, end: 0, demand: vec![] }],
        )
        .is_err());
    }

    #[test]
    fn relabel_and_clamp() {
        let s = shaped().with_id(99);
        assert_eq!(s.id, 99);
        assert_eq!(s.peak(), &[0.6, 0.4]);
        let mut c = shaped();
        c.clamp_demand(&[0.5, 1.0]);
        assert_eq!(c.peak(), &[0.5, 0.4]);
        assert_eq!(c.demand_at(4), Some(&[0.5, 0.3][..]));
        // flat clamp matches the seed's component-wise min
        let mut f = Task::new(0, vec![0.8, 0.2], 0, 1);
        f.clamp_demand(&[0.5, 0.5]);
        assert_eq!(f.peak(), &[0.5, 0.2]);
    }
}
