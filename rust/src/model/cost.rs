//! Node-type cost models (paper Equation 8):
//!
//! ```text
//! cost(B) = sum_d c_d * cap(B,d)^e
//! ```
//!
//! Homogeneous-linear sets every coefficient and the exponent to one;
//! heterogeneous draws coefficients (or takes pricing-table ones) and
//! varies `e` to model non-linear rate curves (e<1: bulk discount,
//! e>1: premium for large shapes).

use super::nodetype::NodeType;

/// Cost model parameters.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Per-dimension coefficients `c_d`.
    pub coefficients: Vec<f64>,
    /// Exponent `e` applied to each capacity component.
    pub exponent: f64,
}

impl CostModel {
    /// Homogeneous linear model: `c_d = 1`, `e = 1` (paper section VI-B).
    pub fn homogeneous(dims: usize) -> Self {
        CostModel { coefficients: vec![1.0; dims], exponent: 1.0 }
    }

    pub fn new(coefficients: Vec<f64>, exponent: f64) -> Self {
        assert!(!coefficients.is_empty());
        assert!(exponent > 0.0, "non-positive exponent");
        CostModel { coefficients, exponent }
    }

    /// Price a capacity vector.
    pub fn price(&self, capacity: &[f64]) -> f64 {
        assert_eq!(capacity.len(), self.coefficients.len());
        capacity
            .iter()
            .zip(&self.coefficients)
            .map(|(&cap, &c)| c * cap.powf(self.exponent))
            .sum()
    }

    /// Re-price a catalog of node-types in place.
    pub fn apply(&self, types: &mut [NodeType]) {
        for b in types {
            b.cost = self.price(&b.capacity);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_is_sum() {
        let m = CostModel::homogeneous(3);
        assert!((m.price(&[0.2, 0.3, 0.5]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exponent_effects() {
        let m_sub = CostModel::new(vec![1.0], 0.5);
        let m_sup = CostModel::new(vec![1.0], 2.0);
        // sub-linear: doubling capacity less than doubles cost
        assert!(m_sub.price(&[0.8]) < 2.0 * m_sub.price(&[0.4]));
        // super-linear: doubling capacity more than doubles cost
        assert!(m_sup.price(&[0.8]) > 2.0 * m_sup.price(&[0.4]));
    }

    #[test]
    fn apply_repricing() {
        let mut types = vec![
            NodeType::new("a", vec![0.5, 0.5], 99.0),
            NodeType::new("b", vec![1.0, 0.2], 99.0),
        ];
        CostModel::new(vec![2.0, 1.0], 1.0).apply(&mut types);
        assert!((types[0].cost - 1.5).abs() < 1e-12);
        assert!((types[1].cost - 2.2).abs() < 1e-12);
    }
}
