//! Timeline trimming (paper section II): only demand-increase slots matter.
//!
//! For capacity constraints, the aggregate load within a node only changes
//! upward when some demand *segment* begins — a task's start is its first
//! segment's start, and between consecutive segment starts the active
//! demand can only shrink (tasks end, or step to their next window at a
//! slot that is itself a segment start). Remapping every slot to the rank
//! of the latest segment start <= slot therefore preserves the
//! feasible-solution set exactly while shrinking T to at most the number
//! of distinct segment starts (= n for flat instances, where this is the
//! seed's distinct-task-start trim, bit-identically).

use super::instance::Instance;
use super::task::{DemandSeg, Task};

/// Result of trimming: the rewritten instance plus the sorted original
/// start slots (`slots[k]` is the original timeslot of trimmed slot `k`),
/// so solutions can be reported against the original timeline.
#[derive(Clone, Debug)]
pub struct Trimmed {
    pub instance: Instance,
    pub slots: Vec<u32>,
}

/// Trim the timeline of `inst` to distinct demand-segment start slots.
///
/// Each segment window `[s, e]` becomes `[rank(s), rank'(e)]` where
/// `rank` is the index of `s` among sorted distinct segment starts and
/// `rank'` maps `e` to the latest start `<= e`. Windows always contain
/// their own start, so every image window is non-empty, and adjacent
/// segments stay contiguous (the successor's start is itself a slot).
pub fn trim(inst: &Instance) -> Trimmed {
    if inst.tasks.is_empty() {
        return Trimmed {
            instance: Instance::new(vec![], inst.node_types.clone(), 1),
            slots: vec![0],
        };
    }
    let mut slots: Vec<u32> = inst
        .tasks
        .iter()
        .flat_map(|u| u.segments().iter().map(|s| s.start))
        .collect();
    slots.sort_unstable();
    slots.dedup();

    let rank_of_start = |s: u32| -> u32 {
        slots.binary_search(&s).expect("start must be a slot") as u32
    };
    // latest start <= e; every window has start <= e so this never underflows
    let rank_of_end = |e: u32| -> u32 {
        match slots.binary_search(&e) {
            Ok(i) => i as u32,
            Err(i) => (i - 1) as u32,
        }
    };

    let tasks: Vec<Task> = inst
        .tasks
        .iter()
        .map(|u| {
            if u.is_flat() {
                // the seed's flat path, unchanged
                let seg = &u.segments()[0];
                Task::new(
                    u.id,
                    seg.demand.clone(),
                    rank_of_start(u.start),
                    rank_of_end(u.end),
                )
            } else {
                let segs: Vec<DemandSeg> = u
                    .segments()
                    .iter()
                    .map(|s| DemandSeg {
                        start: rank_of_start(s.start),
                        end: rank_of_end(s.end),
                        demand: s.demand.clone(),
                    })
                    .collect();
                Task::piecewise(u.id, segs)
            }
        })
        .collect();
    let horizon = slots.len() as u32;
    Trimmed {
        instance: Instance::new(tasks, inst.node_types.clone(), horizon),
        slots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::nodetype::NodeType;

    fn types() -> Vec<NodeType> {
        vec![NodeType::new("a", vec![1.0], 1.0)]
    }

    #[test]
    fn trims_to_starts() {
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.1], 5, 100),
                Task::new(1, vec![0.1], 40, 60),
                Task::new(2, vec![0.1], 5, 39),
            ],
            types(),
            101,
        );
        let tr = trim(&inst);
        assert_eq!(tr.slots, vec![5, 40]);
        assert_eq!(tr.instance.horizon, 2);
        // task 0: [5,100] -> [0,1]; task 1: [40,60] -> [1,1]; task 2: [5,39] -> [0,0]
        assert_eq!((tr.instance.tasks[0].start, tr.instance.tasks[0].end), (0, 1));
        assert_eq!((tr.instance.tasks[1].start, tr.instance.tasks[1].end), (1, 1));
        assert_eq!((tr.instance.tasks[2].start, tr.instance.tasks[2].end), (0, 0));
    }

    #[test]
    fn overlap_preserved() {
        // Pairwise overlap structure at start slots is exactly preserved.
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.1], 0, 9),
                Task::new(1, vec![0.1], 3, 4),
                Task::new(2, vec![0.1], 5, 9),
            ],
            types(),
            10,
        );
        let tr = trim(&inst);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(
                    inst.tasks[i].overlaps(&inst.tasks[j]),
                    tr.instance.tasks[i].overlaps(&tr.instance.tasks[j]),
                    "pair ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn idempotent() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.1], 0, 3), Task::new(1, vec![0.1], 2, 3)],
            types(),
            4,
        );
        let once = trim(&inst);
        let twice = trim(&once.instance);
        assert_eq!(once.instance.horizon, twice.instance.horizon);
        for (a, b) in once.instance.tasks.iter().zip(&twice.instance.tasks) {
            assert_eq!((a.start, a.end), (b.start, b.end));
        }
    }

    #[test]
    fn empty_instance() {
        let inst = Instance::new(vec![], types(), 5);
        let tr = trim(&inst);
        assert_eq!(tr.instance.horizon, 1);
    }

    #[test]
    fn segment_boundaries_become_slots() {
        // A shaped task whose demand *rises* mid-span: the rise slot must
        // survive trimming, or the trimmed instance would hide the peak
        // overlap with task 1.
        let shaped = Task::piecewise(
            0,
            vec![
                DemandSeg { start: 2, end: 49, demand: vec![0.2] },
                DemandSeg { start: 50, end: 99, demand: vec![0.8] },
            ],
        );
        let inst = Instance::new(
            vec![shaped, Task::new(1, vec![0.5], 60, 80)],
            types(),
            100,
        );
        let tr = trim(&inst);
        assert_eq!(tr.slots, vec![2, 50, 60]);
        let t0 = &tr.instance.tasks[0];
        assert!(!t0.is_flat());
        // windows: [2,49] -> [0,0], [50,99] -> [1,2]
        assert_eq!(
            t0.segments()
                .iter()
                .map(|s| (s.start, s.end))
                .collect::<Vec<_>>(),
            vec![(0, 0), (1, 2)]
        );
        // demand at the trimmed slots reproduces the original shape
        assert_eq!(t0.demand_at(0), Some(&[0.2][..]));
        assert_eq!(t0.demand_at(1), Some(&[0.8][..]));
        assert_eq!(t0.demand_at(2), Some(&[0.8][..]));
        // task 1 overlaps the peak window on the trimmed timeline:
        // 0.8 + 0.5 > 1.0 must still be detectable per-slot
        let t1 = &tr.instance.tasks[1];
        assert_eq!((t1.start, t1.end), (2, 2));
    }

    #[test]
    fn flat_instances_trim_exactly_as_before() {
        // flat tasks contribute exactly their start slots — the seed rule
        let inst = Instance::new(
            vec![Task::new(0, vec![0.1], 7, 30), Task::new(1, vec![0.1], 12, 20)],
            types(),
            31,
        );
        let tr = trim(&inst);
        assert_eq!(tr.slots, vec![7, 12]);
        assert!(tr.instance.tasks.iter().all(|t| t.is_flat()));
    }
}
