//! Timeline trimming (paper section II): only task start slots matter.
//!
//! For capacity constraints, the aggregate load within a node only changes
//! at task start times — between consecutive starts the active set can only
//! shrink. Remapping every slot to the rank of the latest start <= slot
//! therefore preserves the feasible-solution set exactly while shrinking
//! T to at most n distinct values.

use super::instance::Instance;
use super::task::Task;

/// Result of trimming: the rewritten instance plus the sorted original
/// start slots (`slots[k]` is the original timeslot of trimmed slot `k`),
/// so solutions can be reported against the original timeline.
#[derive(Clone, Debug)]
pub struct Trimmed {
    pub instance: Instance,
    pub slots: Vec<u32>,
}

/// Trim the timeline of `inst` to distinct task start slots.
///
/// Each task's interval `[s, e]` becomes `[rank(s), rank'(e)]` where
/// `rank` is the index of `s` among sorted distinct starts and `rank'`
/// maps `e` to the latest start `<= e`. Tasks always contain their own
/// start, so the image interval is non-empty.
pub fn trim(inst: &Instance) -> Trimmed {
    if inst.tasks.is_empty() {
        return Trimmed {
            instance: Instance::new(vec![], inst.node_types.clone(), 1),
            slots: vec![0],
        };
    }
    let mut slots: Vec<u32> = inst.tasks.iter().map(|u| u.start).collect();
    slots.sort_unstable();
    slots.dedup();

    let rank_of_start = |s: u32| -> u32 {
        slots.binary_search(&s).expect("start must be a slot") as u32
    };
    // latest start <= e; every task has start <= e so this never underflows
    let rank_of_end = |e: u32| -> u32 {
        match slots.binary_search(&e) {
            Ok(i) => i as u32,
            Err(i) => (i - 1) as u32,
        }
    };

    let tasks: Vec<Task> = inst
        .tasks
        .iter()
        .map(|u| Task::new(u.id, u.demand.clone(), rank_of_start(u.start), rank_of_end(u.end)))
        .collect();
    let horizon = slots.len() as u32;
    Trimmed {
        instance: Instance::new(tasks, inst.node_types.clone(), horizon),
        slots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::nodetype::NodeType;

    fn types() -> Vec<NodeType> {
        vec![NodeType::new("a", vec![1.0], 1.0)]
    }

    #[test]
    fn trims_to_starts() {
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.1], 5, 100),
                Task::new(1, vec![0.1], 40, 60),
                Task::new(2, vec![0.1], 5, 39),
            ],
            types(),
            101,
        );
        let tr = trim(&inst);
        assert_eq!(tr.slots, vec![5, 40]);
        assert_eq!(tr.instance.horizon, 2);
        // task 0: [5,100] -> [0,1]; task 1: [40,60] -> [1,1]; task 2: [5,39] -> [0,0]
        assert_eq!((tr.instance.tasks[0].start, tr.instance.tasks[0].end), (0, 1));
        assert_eq!((tr.instance.tasks[1].start, tr.instance.tasks[1].end), (1, 1));
        assert_eq!((tr.instance.tasks[2].start, tr.instance.tasks[2].end), (0, 0));
    }

    #[test]
    fn overlap_preserved() {
        // Pairwise overlap structure at start slots is exactly preserved.
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.1], 0, 9),
                Task::new(1, vec![0.1], 3, 4),
                Task::new(2, vec![0.1], 5, 9),
            ],
            types(),
            10,
        );
        let tr = trim(&inst);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(
                    inst.tasks[i].overlaps(&inst.tasks[j]),
                    tr.instance.tasks[i].overlaps(&tr.instance.tasks[j]),
                    "pair ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn idempotent() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.1], 0, 3), Task::new(1, vec![0.1], 2, 3)],
            types(),
            4,
        );
        let once = trim(&inst);
        let twice = trim(&once.instance);
        assert_eq!(once.instance.horizon, twice.instance.horizon);
        for (a, b) in once.instance.tasks.iter().zip(&twice.instance.tasks) {
            assert_eq!((a.start, a.end), (b.start, b.end));
        }
    }

    #[test]
    fn empty_instance() {
        let inst = Instance::new(vec![], types(), 5);
        let tr = trim(&inst);
        assert_eq!(tr.instance.horizon, 1);
    }
}
