//! Node-types: purchasable machine shapes with capacity and price.

/// A node-type `B` (paper section II): capacity vector `cap(B,d)` and price
/// `cost(B)`. A purchased replica of a node-type is a *node*.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeType {
    /// Human-readable name (e.g. "n2-standard-8" for GCT-like traces).
    pub name: String,
    /// Capacity along each of the D dimensions, normalized to (0, 1].
    pub capacity: Vec<f64>,
    /// Purchase price of one replica.
    pub cost: f64,
}

impl NodeType {
    pub fn new(name: impl Into<String>, capacity: Vec<f64>, cost: f64) -> Self {
        let name = name.into();
        assert!(!capacity.is_empty(), "node-type {name}: empty capacity");
        assert!(
            capacity.iter().all(|&c| c > 0.0),
            "node-type {name}: non-positive capacity"
        );
        assert!(cost >= 0.0, "node-type {name}: negative cost");
        NodeType { name, capacity, cost }
    }

    pub fn dims(&self) -> usize {
        self.capacity.len()
    }

    /// Capacity offered per unit cost, `sum_d cap(B,d) / cost(B)` — the
    /// node-type ordering key for cross-node-type filling (paper section V-D).
    pub fn capacity_per_cost(&self) -> f64 {
        let total: f64 = self.capacity.iter().sum();
        if self.cost <= 0.0 {
            f64::INFINITY
        } else {
            total / self.cost
        }
    }

    /// Could a task with this demand vector ever fit on an empty node?
    pub fn admits(&self, demand: &[f64]) -> bool {
        demand.iter().zip(&self.capacity).all(|(&d, &c)| d <= c + 1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_and_admit() {
        let b = NodeType::new("small", vec![0.5, 0.25], 3.0);
        assert!((b.capacity_per_cost() - 0.25).abs() < 1e-12);
        assert!(b.admits(&[0.5, 0.2]));
        assert!(!b.admits(&[0.51, 0.2]));
    }

    #[test]
    fn zero_cost_is_infinite_ratio() {
        let b = NodeType::new("free", vec![1.0], 0.0);
        assert!(b.capacity_per_cost().is_infinite());
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        NodeType::new("bad", vec![0.0], 1.0);
    }
}
