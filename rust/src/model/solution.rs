//! Solutions: a purchased multiset of nodes plus a task placement,
//! with an independent feasibility verifier.

use super::instance::Instance;
use super::load::{LoadProfile, Profile};
use super::EPS;

/// One purchased node (a replica of a node-type). `purchase_order` is the
/// sequence number used by first-fit ("node purchased the earliest").
#[derive(Clone, Debug)]
pub struct PlacedNode {
    pub type_idx: usize,
    pub purchase_order: usize,
    /// Indices of the tasks placed in this node.
    pub tasks: Vec<usize>,
}

/// A feasible (or to-be-verified) solution.
#[derive(Clone, Debug, Default)]
pub struct Solution {
    pub nodes: Vec<PlacedNode>,
    /// For each task index, the node index it is placed in.
    pub assignment: Vec<Option<usize>>,
}

/// A feasibility violation found by [`Solution::verify`].
#[derive(Clone, Debug, PartialEq)]
pub enum Violation {
    Unplaced { task: usize },
    DoublyPlaced { task: usize },
    CapacityExceeded { node: usize, timeslot: u32, dim: usize, load: f64, cap: f64 },
    InconsistentAssignment { task: usize },
}

impl Solution {
    pub fn new(n_tasks: usize) -> Self {
        Solution { nodes: Vec::new(), assignment: vec![None; n_tasks] }
    }

    /// Total purchase cost `sum_b cost(b)`.
    pub fn cost(&self, inst: &Instance) -> f64 {
        self.nodes.iter().map(|b| inst.node_types[b.type_idx].cost).sum()
    }

    /// Number of nodes purchased per node-type.
    pub fn nodes_per_type(&self, inst: &Instance) -> Vec<usize> {
        let mut counts = vec![0usize; inst.n_types()];
        for b in &self.nodes {
            counts[b.type_idx] += 1;
        }
        counts
    }

    /// Full feasibility check (paper capacity constraint): every task
    /// placed exactly once, assignment consistent with node task lists,
    /// and for every node, timeslot and dimension the aggregate demand of
    /// active tasks is within capacity. Shaped tasks contribute their
    /// exact per-slot (segment) demand, so the check is strictly per-slot
    /// — a profile whose peaks never coincide passes where a peak-sum
    /// approximation would reject, and an overlap of two high windows is
    /// caught even when each task's average load looks harmless.
    ///
    /// Runs on the indexed [`LoadProfile`]: task aggregation is
    /// O(tasks·D·log T) instead of O(tasks·span·D) and the capacity
    /// sweep is output-sensitive (only overloaded subtrees are walked);
    /// profile allocation is still Θ(T·D) per node — with a larger
    /// constant than the seed's single usage array — so the win shows on
    /// long timelines with long-spanned tasks, not on tiny instances.
    /// Note this shares the segment-tree code with the solvers — for a
    /// check that is *independent* of that code, use
    /// `verify_with::<DenseProfile>` (the property tests cross-check both
    /// backends on every scenario they touch).
    pub fn verify(&self, inst: &Instance) -> Result<(), Vec<Violation>> {
        self.verify_with::<LoadProfile>(inst)
    }

    /// [`Solution::verify`] against an explicit profile backend. Property
    /// tests run the dense reference (`DenseProfile`) to cross-check the
    /// indexed path against the seed's scan.
    pub fn verify_with<P: Profile>(&self, inst: &Instance) -> Result<(), Vec<Violation>> {
        let mut violations = Vec::new();
        let mut seen = vec![0usize; inst.n_tasks()];
        for (bi, node) in self.nodes.iter().enumerate() {
            for &u in &node.tasks {
                seen[u] += 1;
                if self.assignment[u] != Some(bi) {
                    violations.push(Violation::InconsistentAssignment { task: u });
                }
            }
        }
        for u in 0..inst.n_tasks() {
            match seen[u] {
                0 => violations.push(Violation::Unplaced { task: u }),
                1 => {}
                _ => violations.push(Violation::DoublyPlaced { task: u }),
            }
        }
        let dims = inst.dims();
        for (bi, node) in self.nodes.iter().enumerate() {
            let cap = &inst.node_types[node.type_idx].capacity;
            let mut profile = P::new(inst.horizon as usize, cap.clone());
            for &u in &node.tasks {
                profile.add_task(&inst.tasks[u]);
            }
            // collect overloads per dimension, then report them in the
            // seed's (t, d)-ascending order
            let mut over: Vec<(usize, usize, f64)> = Vec::new();
            for d in 0..dims {
                for (t, load) in profile.overloads(d, cap[d] + EPS) {
                    over.push((t, d, load));
                }
            }
            over.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
            for (t, d, load) in over {
                violations.push(Violation::CapacityExceeded {
                    node: bi,
                    timeslot: t as u32,
                    dim: d,
                    load,
                    cap: cap[d],
                });
            }
        }
        if violations.is_empty() {
            Ok(())
        } else {
            Err(violations)
        }
    }

    /// Peak utilization of a node over its busiest (t, d): used by reports.
    pub fn node_peak_utilization(&self, inst: &Instance, node_idx: usize) -> f64 {
        let node = &self.nodes[node_idx];
        let cap = &inst.node_types[node.type_idx].capacity;
        let mut profile = LoadProfile::new(inst.horizon as usize, cap.clone());
        for &u in &node.tasks {
            profile.add_task(&inst.tasks[u]);
        }
        profile.peak_utilization()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::nodetype::NodeType;
    use crate::model::task::Task;

    fn inst() -> Instance {
        Instance::new(
            vec![
                Task::new(0, vec![0.6], 0, 1),
                Task::new(1, vec![0.6], 1, 2),
                Task::new(2, vec![0.6], 3, 3),
            ],
            vec![NodeType::new("a", vec![1.0], 5.0)],
            4,
        )
    }

    #[test]
    fn good_solution_verifies() {
        let inst = inst();
        let mut s = Solution::new(3);
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0, 2] });
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 1, tasks: vec![1] });
        s.assignment = vec![Some(0), Some(1), Some(0)];
        assert!(s.verify(&inst).is_ok());
        assert_eq!(s.cost(&inst), 10.0);
        assert_eq!(s.nodes_per_type(&inst), vec![2]);
    }

    #[test]
    fn overload_detected() {
        let inst = inst();
        let mut s = Solution::new(3);
        // tasks 0 and 1 overlap at t=1 with total demand 1.2 > 1.0
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0, 1, 2] });
        s.assignment = vec![Some(0), Some(0), Some(0)];
        let errs = s.verify(&inst).unwrap_err();
        assert!(errs.iter().any(|v| matches!(
            v,
            Violation::CapacityExceeded { timeslot: 1, .. }
        )));
    }

    #[test]
    fn unplaced_detected() {
        let inst = inst();
        let mut s = Solution::new(3);
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0, 2] });
        s.assignment = vec![Some(0), None, Some(0)];
        let errs = s.verify(&inst).unwrap_err();
        assert!(errs.contains(&Violation::Unplaced { task: 1 }));
    }

    #[test]
    fn double_place_detected() {
        let inst = inst();
        let mut s = Solution::new(3);
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0, 2] });
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 1, tasks: vec![1, 2] });
        s.assignment = vec![Some(0), Some(1), Some(0)];
        let errs = s.verify(&inst).unwrap_err();
        assert!(errs.iter().any(|v| matches!(v, Violation::DoublyPlaced { task: 2 })
            || matches!(v, Violation::InconsistentAssignment { task: 2 })));
    }

    #[test]
    fn shaped_overload_is_per_slot() {
        use crate::model::task::DemandSeg;
        // task 0 ramps up (0.3 then 0.8), task 1 is flat 0.3: the only
        // overload is at slots 2..3 (0.8 + 0.3 > 1.0). A peak-only check
        // would flag the whole joint span; per-slot verification pins the
        // exact slots.
        let inst = Instance::new(
            vec![
                Task::piecewise(
                    0,
                    vec![
                        DemandSeg { start: 0, end: 1, demand: vec![0.3] },
                        DemandSeg { start: 2, end: 3, demand: vec![0.8] },
                    ],
                ),
                Task::new(1, vec![0.3], 0, 3),
            ],
            vec![NodeType::new("a", vec![1.0], 5.0)],
            4,
        );
        let mut s = Solution::new(2);
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0, 1] });
        s.assignment = vec![Some(0), Some(0)];
        let errs = s.verify(&inst).unwrap_err();
        let slots: Vec<u32> = errs
            .iter()
            .filter_map(|v| match v {
                Violation::CapacityExceeded { timeslot, .. } => Some(*timeslot),
                _ => None,
            })
            .collect();
        assert_eq!(slots, vec![2, 3], "{errs:?}");
        // the dense reference verifier agrees
        let dense_errs = s.verify_with::<crate::model::DenseProfile>(&inst).unwrap_err();
        assert_eq!(errs.len(), dense_errs.len());
    }

    #[test]
    fn complementary_shapes_share_a_node() {
        use crate::model::task::DemandSeg;
        // two tasks whose peaks alternate: per-slot load is exactly 1.0,
        // so one node suffices — the reuse a constant-peak model cannot
        // see (0.8 + 0.8 would exceed capacity).
        let mk = |id, hi_first: bool| {
            let (a, b) = if hi_first { (0.8, 0.2) } else { (0.2, 0.8) };
            Task::piecewise(
                id,
                vec![
                    DemandSeg { start: 0, end: 1, demand: vec![a] },
                    DemandSeg { start: 2, end: 3, demand: vec![b] },
                ],
            )
        };
        let inst = Instance::new(
            vec![mk(0, true), mk(1, false)],
            vec![NodeType::new("a", vec![1.0], 5.0)],
            4,
        );
        let mut s = Solution::new(2);
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0, 1] });
        s.assignment = vec![Some(0), Some(0)];
        assert!(s.verify(&inst).is_ok());
        assert!((s.node_peak_utilization(&inst, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn peak_utilization() {
        let inst = inst();
        let mut s = Solution::new(3);
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0, 2] });
        s.nodes.push(PlacedNode { type_idx: 0, purchase_order: 1, tasks: vec![1] });
        s.assignment = vec![Some(0), Some(1), Some(0)];
        assert!((s.node_peak_utilization(&inst, 0) - 0.6).abs() < 1e-12);
    }
}
