//! A TL-Rightsizing problem instance: tasks + node-types + timeline.

use super::nodetype::NodeType;
use super::task::Task;

/// A complete problem instance (paper section II). Dimensions are uniform
/// across tasks and node-types; the timeline is `0..horizon` timeslots.
#[derive(Clone, Debug)]
pub struct Instance {
    pub tasks: Vec<Task>,
    pub node_types: Vec<NodeType>,
    /// Number of timeslots T; every task span lies in [0, horizon).
    pub horizon: u32,
}

impl Instance {
    /// Validate and build. Panics on inconsistent dimensions or spans —
    /// instances come from our own loaders, so this is a programmer error.
    pub fn new(tasks: Vec<Task>, node_types: Vec<NodeType>, horizon: u32) -> Self {
        assert!(!node_types.is_empty(), "no node-types");
        assert!(horizon > 0, "zero horizon");
        let d = node_types[0].dims();
        for b in &node_types {
            assert_eq!(b.dims(), d, "node-type {} dims mismatch", b.name);
        }
        for u in &tasks {
            assert_eq!(u.dims(), d, "task {} dims mismatch", u.id);
            assert!(u.end < horizon, "task {} beyond horizon", u.id);
        }
        Instance { tasks, node_types, horizon }
    }

    /// Number of resource dimensions D.
    pub fn dims(&self) -> usize {
        self.node_types[0].dims()
    }

    /// Number of tasks n.
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Number of node-types m.
    pub fn n_types(&self) -> usize {
        self.node_types.len()
    }

    /// Time-averaged demand/capacity ratio
    /// `r_avg(u,B,d) = avg_dem(u,d)/cap(B,d)`. For flat tasks the average
    /// is the demand itself, so this is the seed's `ratio`.
    #[inline]
    pub fn ratio_avg(&self, u: usize, b: usize, d: usize) -> f64 {
        self.tasks[u].avg()[d] / self.node_types[b].capacity[d]
    }

    /// Peak demand/capacity ratio `r_peak(u,B,d) = peak_dem(u,d)/cap(B,d)`.
    #[inline]
    pub fn ratio_peak(&self, u: usize, b: usize, d: usize) -> f64 {
        self.tasks[u].peak()[d] / self.node_types[b].capacity[d]
    }

    /// Relative demand `h_avg(u|B)` (paper section III), generalized to
    /// shaped tasks as the *time-averaged* relative demand — the natural
    /// reading of the penalty as expected congestion contribution.
    pub fn h_avg(&self, u: usize, b: usize) -> f64 {
        let d = self.dims();
        (0..d).map(|k| self.ratio_avg(u, b, k)).sum::<f64>() / d as f64
    }

    /// Relative demand `h_max(u|B)` (alternative mapping policy),
    /// generalized to shaped tasks as the *peak* relative demand —
    /// `h_max` bounds the worst-case footprint, which a shaped task hits
    /// only at its peak.
    pub fn h_max(&self, u: usize, b: usize) -> f64 {
        (0..self.dims())
            .map(|k| self.ratio_peak(u, b, k))
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Can every task fit on at least one node-type alone? (feasibility
    /// precondition; loaders guarantee it, algorithms assert it).
    /// Admissibility is a peak-demand property.
    pub fn is_feasible(&self) -> bool {
        self.tasks.iter().all(|u| {
            self.node_types.iter().any(|b| b.admits(u.peak()))
        })
    }

    /// Sum of node-type costs `cost(B)` over the catalog — the additive
    /// constant in the approximation bounds (paper Lemma 2).
    pub fn catalog_cost(&self) -> f64 {
        self.node_types.iter().map(|b| b.cost).sum()
    }

    /// Indices of tasks active at timeslot `t`.
    pub fn active_at(&self, t: u32) -> Vec<usize> {
        (0..self.tasks.len())
            .filter(|&u| self.tasks[u].active_at(t))
            .collect()
    }

    /// Treat every task as perpetually active (paper section VI-F,
    /// "no-timeline" comparison): all spans become [0, 0], horizon 1. A
    /// shaped task collapses to its *peak* demand — the capacity a
    /// timeline-agnostic sizer would have to reserve for it.
    pub fn collapse_timeline(&self) -> Instance {
        let tasks = self
            .tasks
            .iter()
            .map(|u| Task::new(u.id, u.peak().to_vec(), 0, 0))
            .collect();
        Instance::new(tasks, self.node_types.clone(), 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn tiny() -> Instance {
        Instance::new(
            vec![
                Task::new(0, vec![0.2, 0.4], 0, 2),
                Task::new(1, vec![0.5, 0.1], 3, 5),
            ],
            vec![
                NodeType::new("a", vec![1.0, 1.0], 10.0),
                NodeType::new("b", vec![0.5, 0.5], 6.0),
            ],
            6,
        )
    }

    #[test]
    fn accessors() {
        let inst = tiny();
        assert_eq!(inst.dims(), 2);
        assert_eq!(inst.n_tasks(), 2);
        assert_eq!(inst.n_types(), 2);
        assert!((inst.ratio_avg(0, 1, 1) - 0.8).abs() < 1e-12);
        assert!((inst.ratio_peak(0, 1, 1) - 0.8).abs() < 1e-12);
        assert!((inst.h_avg(0, 0) - 0.3).abs() < 1e-12);
        assert!((inst.h_max(0, 0) - 0.4).abs() < 1e-12);
        assert!((inst.catalog_cost() - 16.0).abs() < 1e-12);
        assert!(inst.is_feasible());
    }

    #[test]
    fn active_sets() {
        let inst = tiny();
        assert_eq!(inst.active_at(0), vec![0]);
        assert_eq!(inst.active_at(3), vec![1]);
        assert!(inst.active_at(6.min(inst.horizon - 1)).len() <= 2);
    }

    #[test]
    fn shaped_penalties_split_avg_vs_peak() {
        use crate::model::task::DemandSeg;
        // demand 0.2 for 2 slots then 0.6 for 2 slots: avg 0.4, peak 0.6
        let inst = Instance::new(
            vec![Task::piecewise(
                0,
                vec![
                    DemandSeg { start: 0, end: 1, demand: vec![0.2] },
                    DemandSeg { start: 2, end: 3, demand: vec![0.6] },
                ],
            )],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            4,
        );
        assert!((inst.h_avg(0, 0) - 0.4).abs() < 1e-12);
        assert!((inst.h_max(0, 0) - 0.6).abs() < 1e-12);
        assert!(inst.is_feasible());
        // collapsing reserves the peak
        let c = inst.collapse_timeline();
        assert_eq!(c.tasks[0].peak(), &[0.6]);
        assert!(c.tasks[0].is_flat());
    }

    #[test]
    fn collapse() {
        let c = tiny().collapse_timeline();
        assert_eq!(c.horizon, 1);
        assert!(c.tasks.iter().all(|u| u.start == 0 && u.end == 0));
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_rejected() {
        Instance::new(
            vec![Task::new(0, vec![0.1], 0, 0)],
            vec![NodeType::new("a", vec![1.0, 1.0], 1.0)],
            1,
        );
    }
}
