//! Workload deltas: the typed mutations a plan session replays onto a
//! live instance.
//!
//! The paper's cold-start formulation freezes the workload before the
//! single solve; deployed clusters live in the dynamic arrival/departure
//! setting (DVBP, arXiv 2304.08648) and the continuous reconfiguration
//! loop of Eva (arXiv 2503.07437): tasks arrive (`Admit`), leave
//! (`Retire`), change shape or window (`Reshape`), and the purchasable
//! catalog itself gets repriced (`Reprice`). Each variant carries fully
//! validated model values — `Task`s and `NodeType`s, not raw JSON — so
//! the session layer applies them without re-parsing; the wire grammar
//! lives in `io::delta`.
//!
//! Tasks are addressed by their stable [`Task::id`] (never by instance
//! index, which reshuffles when the session compacts over a retirement).

use super::nodetype::NodeType;
use super::task::Task;

/// One mutation of a live instance.
#[derive(Clone, Debug)]
pub enum Delta {
    /// New tasks enter the workload (flat or piecewise profiles). Ids
    /// must be fresh: no collision with a live task or with each other.
    Admit { tasks: Vec<Task> },
    /// Live tasks leave; their capacity is released immediately.
    Retire { ids: Vec<u64> },
    /// A live task's demand profile and/or active window is replaced;
    /// the replacement task carries the same id.
    Reshape { task: Task },
    /// The node-type catalog is replaced (prices and/or capacities).
    Reprice { node_types: Vec<NodeType> },
}

impl Delta {
    /// Wire/report verb for this delta kind.
    pub fn op(&self) -> &'static str {
        match self {
            Delta::Admit { .. } => "admit",
            Delta::Retire { .. } => "retire",
            Delta::Reshape { .. } => "reshape",
            Delta::Reprice { .. } => "reprice",
        }
    }

    /// How many tasks the delta touches (catalog changes touch none).
    pub fn n_touched(&self) -> usize {
        match self {
            Delta::Admit { tasks } => tasks.len(),
            Delta::Retire { ids } => ids.len(),
            Delta::Reshape { .. } => 1,
            Delta::Reprice { .. } => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_and_counts() {
        let admit = Delta::Admit { tasks: vec![Task::new(7, vec![0.1], 0, 1)] };
        assert_eq!(admit.op(), "admit");
        assert_eq!(admit.n_touched(), 1);
        let retire = Delta::Retire { ids: vec![1, 2, 3] };
        assert_eq!(retire.op(), "retire");
        assert_eq!(retire.n_touched(), 3);
        let reshape = Delta::Reshape { task: Task::new(1, vec![0.2], 0, 0) };
        assert_eq!(reshape.op(), "reshape");
        assert_eq!(reshape.n_touched(), 1);
        let reprice =
            Delta::Reprice { node_types: vec![NodeType::new("a", vec![1.0], 2.0)] };
        assert_eq!(reprice.op(), "reprice");
        assert_eq!(reprice.n_touched(), 0);
    }
}
