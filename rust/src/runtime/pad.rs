//! Zero-padding a structured mapping LP into a shape bucket.
//!
//! Padding semantics (mirrors python/compile/model.py):
//!   - padded tasks: zero activity column, zero ratios, taskmask 0;
//!   - padded node-types: typemask 0 (x columns projected to 0), cost 0,
//!     rho rows 0;
//!   - padded timeslots / dims: act rows 0 and rho 0 make them inert.

use crate::lp::MappingLp;

use super::artifact::Bucket;
use super::client::HostTensor;

/// All padded input tensors for one PDHG chunk call (excluding state).
pub struct PaddedLp {
    pub act: HostTensor,      // (T, N)
    pub r: HostTensor,        // (N, M, D)
    pub rho: HostTensor,      // (M, T, D)
    pub cost: HostTensor,     // (M,)
    pub taskmask: HostTensor, // (N,)
    pub typemask: HostTensor, // (M,)
}

pub fn pad(lp: &MappingLp, bucket: &Bucket) -> PaddedLp {
    let (n, m, dims, t) = (lp.n, lp.m, lp.dims, lp.t);
    let (pn, pm, pt, pd) = (bucket.n, bucket.m, bucket.t, bucket.d);
    assert!(bucket.fits(n, m, t, dims), "bucket too small");
    // The artifact's (act, r) factorization assumes one constant ratio
    // block per task; shaped (multi-segment) LPs must use the native
    // backend (ArtifactSolver bails before reaching here).
    assert!(lp.is_flat(), "artifact padding requires flat demand profiles");

    let mut act = vec![0.0f32; pt * pn];
    for (u, &(s, e)) in lp.spans.iter().enumerate() {
        for ts in s..=e {
            act[ts as usize * pn + u] = 1.0;
        }
    }
    let mut r = vec![0.0f32; pn * pm * pd];
    for u in 0..n {
        let s = lp.seg_off[u]; // single segment per task (flat)
        for b in 0..m {
            for d in 0..dims {
                r[(u * pm + b) * pd + d] = lp.seg_ratio(s, b, d) as f32;
            }
        }
    }
    let mut rho = vec![0.0f32; pm * pt * pd];
    for b in 0..m {
        for ts in 0..t {
            for d in 0..dims {
                rho[(b * pt + ts) * pd + d] = lp.rho_at(b, d) as f32;
            }
        }
    }
    let mut cost = vec![0.0f32; pm];
    for b in 0..m {
        cost[b] = lp.costs[b] as f32;
    }
    let mut taskmask = vec![0.0f32; pn];
    taskmask[..n].fill(1.0);
    let mut typemask = vec![0.0f32; pm];
    typemask[..m].fill(1.0);

    PaddedLp {
        act: HostTensor::new(vec![pt as i64, pn as i64], act),
        r: HostTensor::new(vec![pn as i64, pm as i64, pd as i64], r),
        rho: HostTensor::new(vec![pm as i64, pt as i64, pd as i64], rho),
        cost: HostTensor::new(vec![pm as i64], cost),
        taskmask: HostTensor::new(vec![pn as i64], taskmask),
        typemask: HostTensor::new(vec![pm as i64], typemask),
    }
}

/// Extract the real (n, m) block of a padded (N, M) x-matrix into f64.
pub fn unpad_x(lp: &MappingLp, bucket: &Bucket, x: &[f32]) -> Vec<f64> {
    let mut out = vec![0.0f64; lp.n * lp.m];
    for u in 0..lp.n {
        for b in 0..lp.m {
            out[u * lp.m + b] = x[u * bucket.m + b] as f64;
        }
    }
    out
}

/// Extract real duals y from padded (M, T, D) layout into the native
/// (b*t + ts)*dims + d layout.
pub fn unpad_y(lp: &MappingLp, bucket: &Bucket, y: &[f32]) -> Vec<f64> {
    let mut out = vec![0.0f64; lp.m * lp.t * lp.dims];
    for b in 0..lp.m {
        for ts in 0..lp.t {
            for d in 0..lp.dims {
                out[(b * lp.t + ts) * lp.dims + d] =
                    y[(b * bucket.t + ts) * bucket.d + d] as f64;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::model::trim;

    fn bucket() -> Bucket {
        Bucket {
            name: "t".into(),
            n: 32,
            m: 4,
            t: 16,
            d: 4,
            chunk_iters: 10,
            pdhg: String::new(),
            power: String::new(),
            penalty: String::new(),
        }
    }

    fn lp() -> MappingLp {
        let inst = generate(
            &SynthParams { n: 10, m: 3, dims: 2, horizon: 8, ..Default::default() },
            1,
        );
        MappingLp::from_instance(&trim(&inst).instance)
    }

    #[test]
    fn padding_layout() {
        let lp = lp();
        let b = bucket();
        let p = pad(&lp, &b);
        assert_eq!(p.act.shape, vec![16, 32]);
        assert_eq!(p.r.shape, vec![32, 4, 4]);
        // active exactly over the span
        let (s, e) = lp.spans[0];
        for ts in 0..16usize {
            let want = ts >= s as usize && ts <= e as usize;
            assert_eq!(p.act.data[ts * 32] == 1.0, want, "ts {ts}");
        }
        // padded regions are zero
        assert!(p.act.data.iter().skip(10).step_by(32).all(|&v| v == 0.0 || v == 1.0));
        for u in 10..32 {
            for bb in 0..4 {
                for d in 0..4 {
                    assert_eq!(p.r.data[(u * 4 + bb) * 4 + d], 0.0);
                }
            }
        }
        assert_eq!(p.taskmask.data.iter().sum::<f32>(), 10.0);
        assert_eq!(p.typemask.data.iter().sum::<f32>(), 3.0);
    }

    #[test]
    fn unpad_roundtrip() {
        let lp = lp();
        let b = bucket();
        // fabricate a padded x with recognizable entries
        let mut x = vec![0.0f32; b.n * b.m];
        for u in 0..lp.n {
            for bb in 0..lp.m {
                x[u * b.m + bb] = (u * 10 + bb) as f32;
            }
        }
        let out = unpad_x(&lp, &b, &x);
        assert_eq!(out[2 * lp.m + 1], 21.0);
        let mut y = vec![0.0f32; b.m * b.t * b.d];
        y[(1 * b.t + 2) * b.d + 1] = 7.0;
        let oy = unpad_y(&lp, &b, &y);
        assert_eq!(oy[(1 * lp.t + 2) * lp.dims + 1], 7.0);
    }
}
