//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! PJRT client from the L3 hot path (python is build-time only).

pub mod artifact;
pub mod client;
pub mod pad;
pub mod pdhg_exec;

pub use artifact::Manifest;
pub use client::{Engine, HostTensor};
pub use pdhg_exec::{ArtifactOptions, ArtifactSolver};
