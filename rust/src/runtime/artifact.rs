//! Artifact manifest + shape-bucket selection.
//!
//! python/compile/aot.py pads every program into fixed shape buckets and
//! records them in artifacts/manifest.json; this module picks the smallest
//! bucket an instance fits and resolves artifact file paths.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json;

#[derive(Clone, Debug)]
pub struct Bucket {
    pub name: String,
    pub n: usize,
    pub m: usize,
    pub t: usize,
    pub d: usize,
    pub chunk_iters: usize,
    pub pdhg: String,
    pub power: String,
    pub penalty: String,
}

impl Bucket {
    pub fn fits(&self, n: usize, m: usize, t: usize, d: usize) -> bool {
        n <= self.n && m <= self.m && t <= self.t && d <= self.d
    }

    /// Padded problem volume — the bucket-selection ordering key.
    pub fn volume(&self) -> usize {
        self.n * self.m * self.t * self.d
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub buckets: Vec<Bucket>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut buckets = Vec::new();
        for b in v.get("buckets").as_arr().context("manifest: buckets")? {
            buckets.push(Bucket {
                name: b.get("name").as_str().context("bucket name")?.to_string(),
                n: b.get("n").as_usize().context("bucket n")?,
                m: b.get("m").as_usize().context("bucket m")?,
                t: b.get("t").as_usize().context("bucket t")?,
                d: b.get("d").as_usize().context("bucket d")?,
                chunk_iters: b.get("chunk_iters").as_usize().context("chunk_iters")?,
                pdhg: b.get("pdhg").as_str().context("pdhg file")?.to_string(),
                power: b.get("power").as_str().context("power file")?.to_string(),
                penalty: b.get("penalty").as_str().context("penalty file")?.to_string(),
            });
        }
        anyhow::ensure!(!buckets.is_empty(), "manifest has no buckets");
        Ok(Manifest { dir: dir.to_path_buf(), buckets })
    }

    /// Default artifact directory: $TLRS_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("TLRS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest bucket that fits the given logical shape.
    pub fn select(&self, n: usize, m: usize, t: usize, d: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.fits(n, m, t, d))
            .min_by_key(|b| b.volume())
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_from(text: &str) -> Manifest {
        let dir = std::env::temp_dir().join(format!("tlrs_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        Manifest::load(&dir).unwrap()
    }

    fn sample() -> Manifest {
        manifest_from(
            r#"{"buckets":[
                {"name":"s","n":64,"m":4,"t":16,"d":2,"chunk_iters":10,
                 "pdhg":"p_s","power":"w_s","penalty":"y_s"},
                {"name":"l","n":512,"m":8,"t":64,"d":4,"chunk_iters":10,
                 "pdhg":"p_l","power":"w_l","penalty":"y_l"}
            ]}"#,
        )
    }

    #[test]
    fn selects_smallest_fitting() {
        let m = sample();
        assert_eq!(m.select(50, 4, 10, 2).unwrap().name, "s");
        assert_eq!(m.select(100, 4, 10, 2).unwrap().name, "l");
        assert!(m.select(1000, 4, 10, 2).is_none());
        assert!(m.select(50, 4, 10, 8).is_none());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.buckets.is_empty());
            for b in &m.buckets {
                assert!(m.path_of(&b.pdhg).exists(), "{} missing", b.pdhg);
                assert!(m.path_of(&b.power).exists());
                assert!(m.path_of(&b.penalty).exists());
            }
        }
    }

    #[test]
    fn rejects_empty() {
        let dir = std::env::temp_dir().join(format!("tlrs_manifest_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"buckets":[]}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
