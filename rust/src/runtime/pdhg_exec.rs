//! The AOT-artifact LP backend: drives the compiled JAX/Pallas PDHG chunk
//! through PJRT until convergence.
//!
//! One artifact call = one fixed-length chunk of PDHG iterations (state in,
//! state out + diagnostics). Rust owns the outer loop: restart-to-the-
//! better-iterate (PDLP-style), primal-weight adaptation, and the stopping
//! rule — exactly mirroring lp::pdhg's chunk boundary logic so the two
//! backends are interchangeable.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::lp::solver::{MappingSolution, MappingSolver};
use crate::lp::MappingLp;

use super::artifact::{Bucket, Manifest};
use super::client::{Engine, HostTensor};
use super::pad::{pad, unpad_x, unpad_y, PaddedLp};

/// Options for the artifact-backed solve.
#[derive(Clone, Debug)]
pub struct ArtifactOptions {
    pub max_chunks: usize,
    pub tol: f32,
    pub gap_tol: f32,
    /// See lp::pdhg::PdhgOptions::adapt_omega (default off).
    pub adapt_omega: bool,
}

impl Default for ArtifactOptions {
    fn default() -> Self {
        // f32 state: feasibility plateaus near 1e-5-1e-6
        ArtifactOptions { max_chunks: 400, tol: 3e-4, gap_tol: 3e-4, adapt_omega: false }
    }
}

/// MappingSolver backend executing the AOT artifacts.
pub struct ArtifactSolver {
    engine: Arc<Engine>,
    manifest: Manifest,
    pub opts: ArtifactOptions,
}

impl ArtifactSolver {
    pub fn new(engine: Arc<Engine>, manifest: Manifest) -> Self {
        ArtifactSolver { engine, manifest, opts: ArtifactOptions::default() }
    }

    /// Load the default manifest and CPU engine.
    pub fn from_default_dir() -> Result<Self> {
        let manifest = Manifest::load(&Manifest::default_dir())?;
        Ok(Self::new(Arc::new(Engine::cpu()?), manifest))
    }

    pub fn bucket_for(&self, lp: &MappingLp) -> Option<&Bucket> {
        self.manifest.select(lp.n, lp.m, lp.t, lp.dims)
    }

    /// The bucket table this solver routes through (the planner keeps a
    /// copy for routing decisions when the solver itself is hidden
    /// behind a dedicated serial thread).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn power_norm(&self, bucket: &Bucket, padded: &PaddedLp) -> Result<f32> {
        let exe = self.engine.load(&self.manifest.path_of(&bucket.power))?;
        let out = exe.run(&[padded.act.clone(), padded.r.clone(), padded.rho.clone()])?;
        let norm = out[0].data[0];
        anyhow::ensure!(norm.is_finite() && norm > 0.0, "bad operator norm {norm}");
        Ok(norm)
    }
}

fn score(diag: &[f32]) -> f32 {
    diag.iter().copied().fold(0.0f32, f32::max)
}

impl MappingSolver for ArtifactSolver {
    fn solve_mapping(&self, lp: &MappingLp) -> Result<MappingSolution> {
        // The compiled artifact multiplies a per-task ratio tensor by a
        // 0/1 activity matrix, which cannot express per-slot (segment)
        // coefficients. The planner's Auto mode never routes shaped
        // instances here; an explicit --backend artifact gets this error.
        anyhow::ensure!(
            lp.is_flat(),
            "artifact backend supports constant (flat) demand profiles only; \
             shaped tasks need --backend native"
        );
        let bucket = self
            .bucket_for(lp)
            .with_context(|| {
                format!(
                    "no artifact bucket fits (n={}, m={}, t={}, d={}); \
                     use the native backend",
                    lp.n, lp.m, lp.t, lp.dims
                )
            })?
            .clone();
        let padded = pad(lp, &bucket);
        let norm = self.power_norm(&bucket, &padded)?;
        let exe = self.engine.load(&self.manifest.path_of(&bucket.pdhg))?;

        let (pn, pm, pt, pd) = (bucket.n as i64, bucket.m as i64, bucket.t as i64, bucket.d as i64);
        let mut x = HostTensor::zeros(vec![pn, pm]);
        let mut alpha = HostTensor::zeros(vec![pm]);
        let mut y = HostTensor::zeros(vec![pm, pt, pd]);
        let mut w = HostTensor::zeros(vec![pn]);

        let base = 0.9 / norm;
        let mut omega = 1.0f32;
        let mut iterations = 0usize;
        let mut converged = false;
        let mut best_diag = [f32::INFINITY; 4];

        for _ in 0..self.opts.max_chunks {
            let tau = HostTensor::scalar(base * omega);
            let sigma = HostTensor::scalar(base / omega);
            let out = exe.run(&[
                padded.act.clone(),
                padded.r.clone(),
                padded.rho.clone(),
                padded.cost.clone(),
                padded.taskmask.clone(),
                padded.typemask.clone(),
                x.clone(),
                alpha.clone(),
                y.clone(),
                w.clone(),
                tau,
                sigma,
            ])?;
            anyhow::ensure!(out.len() == 9, "pdhg artifact returned {} outputs", out.len());
            let diag = &out[8].data;
            anyhow::ensure!(diag.len() == 8, "diag length {}", diag.len());
            let (last, avg) = (&diag[..4], &diag[4..]);
            iterations += bucket.chunk_iters;

            // restart from the better of {last, average}
            let use_avg = score(avg) < score(last);
            let pick = if use_avg { 4..8 } else { 0..4 };
            x = out[if use_avg { 4 } else { 0 }].clone();
            alpha = out[if use_avg { 5 } else { 1 }].clone();
            y = out[if use_avg { 6 } else { 2 }].clone();
            w = out[if use_avg { 7 } else { 3 }].clone();
            let d = &diag[pick];
            best_diag = [d[0], d[1], d[2], d[3]];

            if d[0].max(d[1]) <= self.opts.tol && d[3] <= self.opts.gap_tol {
                converged = true;
                break;
            }
            if self.opts.adapt_omega {
                let pri = d[0].max(d[1]).max(1e-10);
                let dua = d[2].max(1e-10);
                omega = (omega * (pri / dua).sqrt().clamp(0.5, 2.0)).clamp(1e-3, 1e3);
            }
        }
        let _ = best_diag;

        let xs = unpad_x(lp, &bucket, &x.data);
        let ys = unpad_y(lp, &bucket, &y.data);
        let objective: f64 = lp
            .costs
            .iter()
            .zip(alpha.data.iter())
            .map(|(c, &a)| c * a as f64)
            .sum();
        Ok(MappingSolution { x: xs, y: ys, objective, converged, iterations })
    }

    fn name(&self) -> &'static str {
        "pdhg-artifact"
    }
}

/// Penalty scoring through the AOT penalty artifact — used to cross-check
/// the L1 kernel numbers against the native implementation at runtime.
pub fn penalty_scores_artifact(
    solver: &ArtifactSolver,
    inst: &crate::model::Instance,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let (n, m, dims) = (inst.n_tasks(), inst.n_types(), inst.dims());
    let bucket = solver
        .manifest
        .select(n, m, 1, dims)
        .context("no bucket for penalty scoring")?
        .clone();
    let (pn, pm, pd) = (bucket.n, bucket.m, bucket.d);
    anyhow::ensure!(
        inst.tasks.iter().all(|t| t.is_flat()),
        "penalty artifact cross-check supports flat demand profiles only"
    );
    let mut dem = vec![0.0f32; pn * pd];
    for u in 0..n {
        for d in 0..dims {
            dem[u * pd + d] = inst.tasks[u].peak()[d] as f32;
        }
    }
    // capinv for padded types/dims: zero => zero scores (harmless)
    let mut capinv = vec![0.0f32; pm * pd];
    let mut cost = vec![0.0f32; pm];
    for b in 0..m {
        cost[b] = inst.node_types[b].cost as f32;
        for d in 0..dims {
            capinv[b * pd + d] = (1.0 / inst.node_types[b].capacity[d]) as f32;
        }
    }
    let exe = solver.engine.load(&solver.manifest.path_of(&bucket.penalty))?;
    let out = exe.run(&[
        HostTensor::new(vec![pn as i64, pd as i64], dem),
        HostTensor::new(vec![pm as i64, pd as i64], capinv),
        HostTensor::new(vec![pm as i64], cost),
    ])?;
    anyhow::ensure!(out.len() == 3, "penalty artifact outputs");
    // NOTE: the kernel divides by the padded D; rescale to the real D.
    let scale = pd as f64 / dims as f64;
    let take = |t: &HostTensor, rescale: bool| -> Vec<f64> {
        let mut v = vec![0.0f64; n * m];
        for u in 0..n {
            for b in 0..m {
                let raw = t.data[u * pm + b] as f64;
                v[u * m + b] = if rescale { raw * scale } else { raw };
            }
        }
        v
    };
    Ok((take(&out[0], true), take(&out[1], false)))
}

#[cfg(test)]
mod tests {
    // Integration coverage lives in rust/tests/integration_runtime.rs
    // (needs built artifacts). Unit-testable pieces are in pad.rs/artifact.rs.
}
