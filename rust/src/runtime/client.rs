//! PJRT execution wrapper around the `xla` crate.
//!
//! Loads AOT artifacts (HLO *text* — see python/compile/aot.py for why not
//! serialized protos), compiles them once on the CPU PJRT client, and
//! executes with f32 host buffers. Python never runs here; the artifacts
//! are self-contained.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A host-side f32 tensor (row-major) handed to / received from PJRT.
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub shape: Vec<i64>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<i64>, data: Vec<f32>) -> Self {
        let numel: i64 = shape.iter().product();
        assert_eq!(numel as usize, data.len(), "shape/data mismatch");
        HostTensor { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    pub fn zeros(shape: Vec<i64>) -> Self {
        let numel: i64 = shape.iter().product();
        HostTensor { shape, data: vec![0.0; numel as usize] }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // rank-0: reshape to scalar
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&self.shape)?)
        }
    }

    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<i64> = shape.dims().to_vec();
        let data = lit.to_vec::<f32>()?;
        Ok(HostTensor { shape: dims, data })
    }
}

/// One compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with the given inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()
            .with_context(|| format!("building inputs for {}", self.name))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", self.name))?;
        // aot.py lowers with return_tuple=True: always a tuple, possibly 1-ary
        let parts = out.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

/// The PJRT client with a compile cache keyed by artifact path.
pub struct Engine {
    client: xla::PjRtClient,
    // BTreeMap, not HashMap: iteration/order on any result-adjacent path
    // must be deterministic (lint rule `unordered-iter`), and a compile cache
    // this small gains nothing from hashing.
    cache: Mutex<BTreeMap<PathBuf, std::sync::Arc<Executable>>>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let arc = std::sync::Arc::new(Executable { exe, name });
        self.cache.lock().unwrap().insert(path.to_path_buf(), arc.clone());
        Ok(arc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shapes() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
        let s = HostTensor::scalar(4.0);
        assert!(s.shape.is_empty());
        let z = HostTensor::zeros(vec![4]);
        assert_eq!(z.data, vec![0.0; 4]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::new(vec![2, 2], vec![0.0; 3]);
    }
}
