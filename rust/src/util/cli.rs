//! Tiny CLI argument parser (no clap in the vendored dependency universe).
//!
//! Grammar: `tlrs <subcommand> [positional...] [--flag] [--key value]...`
//! Flags may be given as `--key value` or `--key=value`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding the program name).
    pub fn parse(argv: &[String]) -> Args {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(stripped.to_string(), it.next().unwrap().clone());
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        args
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect("integer flag")).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().expect("float flag")).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().expect("integer flag")).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("solve pos1 --input x.json --seed 7 --quick");
        assert_eq!(a.subcommand, "solve");
        assert_eq!(a.get("input"), Some("x.json"));
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.has_flag("quick"));
        assert_eq!(a.positional, vec!["pos1"]);
        // a value-less flag followed by a positional binds greedily:
        let b = parse("solve --quick pos1");
        assert_eq!(b.get("quick"), Some("pos1"));
    }

    #[test]
    fn eq_form() {
        let a = parse("gen --n=100 --demand=0.01,0.1");
        assert_eq!(a.get_usize("n", 0), 100);
        assert_eq!(a.get("demand"), Some("0.01,0.1"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("figures all --quick");
        assert_eq!(a.subcommand, "figures");
        assert_eq!(a.positional, vec!["all"]);
        assert!(a.has_flag("quick"));
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.get_or("figure", "all"), "all");
        assert_eq!(a.get_f64("e", 1.0), 1.0);
    }
}
