//! Minimal JSON substrate (parser + writer), built from scratch because the
//! vendored dependency universe has no serde_json. Handles the full JSON
//! grammar needed by the artifact manifest, instance files, experiment
//! configs and the planning service protocol.
//!
//! This is the **cold tier** of the two-tier wire layer. It materializes a
//! full DOM (`BTreeMap` objects, heap `String`s) and is the canonical
//! definition of accepted grammar, error messages/positions, and output
//! formatting. The **hot tier** — `util::wire`'s streaming `JsonPull`
//! parser and `JsonWriter` direct-write serializer, plus the typed
//! decoders in `io::files` / `io::delta` and the service request
//! envelope — decodes the high-volume shapes (inline instances, task
//! `segments` arrays, delta objects) straight into `Task`/`Delta`/
//! `Instance` and writes responses without building a tree. The hot tier
//! is byte-equivalent by construction: typed decoders bail to this DOM
//! path on any surprise, and `tests/prop_wire.rs` pins parser/writer
//! equivalence differentially. Cold shapes (artifact manifests, configs,
//! workload specs) stay on this module.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| num_is_usize(*x)).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; Null when absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Convenience: numeric array -> Vec<f64>.
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ----- serialization ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // lint:allow(float-ord): fract() == 0.0 is the exact integrality test
                // for the canonical integer print form; no tolerance is wanted here.
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Largest f64 at which every integer is still exactly representable
/// (2^53). Above it, `as usize` silently lands on a neighboring value,
/// so an id/index that large was never what the sender meant.
pub const MAX_SAFE_INT: f64 = 9_007_199_254_740_992.0;

/// Is this f64 an exact, in-range usize? Shared by [`Json::as_usize`]
/// and the typed streaming decoders in `io` so both tiers accept the
/// same integers. Rejects negatives, fractions, anything above
/// [`MAX_SAFE_INT`], and non-finite values (`inf.fract()` is NaN).
pub fn num_is_usize(x: f64) -> bool {
    // lint:allow(float-ord): exact integrality test for the usize
    // fast-path — a fractional part must reject, however small.
    x >= 0.0 && x.fract() == 0.0 && x <= MAX_SAFE_INT
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ----- parsing ---------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let start = self.i;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":[1,2.5,-3e2],"b":"x\"y","c":true,"d":null,"e":{}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "hi", "a": [1.5, 2]}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(3));
        assert_eq!(v.get("s").as_str(), Some("hi"));
        assert_eq!(v.get("a").to_f64_vec(), Some(vec![1.5, 2.0]));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""A\n\tπ""#).unwrap();
        assert_eq!(v.as_str(), Some("A\n\tπ"));
        // serialize control chars back out safely
        let s = Json::Str("a\u{1}b".into()).to_string();
        assert_eq!(s, "\"a\\u0001b\"");
    }

    #[test]
    fn nested_depth() {
        let src = "[".repeat(50) + &"]".repeat(50);
        assert!(parse(&src).is_ok());
    }

    #[test]
    fn int_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn as_usize_rejects_unsafe_integers() {
        // at 2^53 integers are still exact
        assert_eq!(Json::Num(MAX_SAFE_INT).as_usize(), Some(9_007_199_254_740_992));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        // 2^53 + 2 is the next representable f64 above it — a truncating
        // `as usize` used to accept these (and 1e300!) silently
        assert_eq!(Json::Num(9_007_199_254_740_994.0).as_usize(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }

    #[test]
    fn ws_tolerated() {
        let v = parse(" {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").to_f64_vec(), Some(vec![1.0, 2.0]));
    }
}
