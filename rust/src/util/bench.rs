//! Micro-benchmark harness (criterion is not in the vendored dependency
//! universe). Auto-calibrates iteration counts, reports mean / stddev /
//! min over samples, and guards against dead-code elimination.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub samples: usize,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}  ±{:>10}  (min {:>12}, {} samples x {} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.min_ns),
            self.samples,
            self.iters_per_sample
        )
    }

    /// Machine-readable form for `BENCH_*.json` artifacts.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("std_ns", Json::Num(self.std_ns)),
            ("min_ns", Json::Num(self.min_ns)),
            ("samples", Json::Num(self.samples as f64)),
            ("iters_per_sample", Json::Num(self.iters_per_sample as f64)),
        ])
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark a closure returning any value (black-boxed). Targets
/// ~`budget` of wall time split over `samples` samples.
pub fn bench<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // calibrate: how many iters fit in budget/samples?
    let samples = 10usize;
    let t0 = Instant::now();
    black_box(f());
    let one = t0.elapsed().as_nanos().max(1) as f64;
    let per_sample = budget.as_nanos() as f64 / samples as f64;
    let iters = (per_sample / one).clamp(1.0, 1_000_000.0) as u64;

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            black_box(f());
        }
        times.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    let res = BenchResult {
        name: name.to_string(),
        mean_ns: stats::mean(&times),
        std_ns: stats::stddev(&times),
        min_ns: stats::min(&times),
        samples,
        iters_per_sample: iters,
    };
    println!("{}", res.report_line());
    res
}

/// Quick variant for expensive end-to-end benches: fixed sample count,
/// one iteration per sample.
pub fn bench_n<T>(name: &str, samples: usize, mut f: impl FnMut() -> T) -> BenchResult {
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        black_box(f());
        times.push(t.elapsed().as_nanos() as f64);
    }
    let res = BenchResult {
        name: name.to_string(),
        mean_ns: stats::mean(&times),
        std_ns: stats::stddev(&times),
        min_ns: stats::min(&times),
        samples,
        iters_per_sample: 1,
    };
    println!("{}", res.report_line());
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop-add", Duration::from_millis(20), || 1u64 + 2);
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.samples, 10);
    }

    #[test]
    fn bench_n_runs() {
        let r = bench_n("sleepless", 3, || std::thread::sleep(Duration::from_micros(50)));
        assert!(r.mean_ns >= 50_000.0 * 0.5);
        assert_eq!(r.samples, 3);
    }

    #[test]
    fn to_json_has_fields() {
        let r = bench("json-probe", Duration::from_millis(5), || 2u64 * 3);
        let j = r.to_json();
        assert_eq!(j.get("name").as_str(), Some("json-probe"));
        assert!(j.get("mean_ns").as_f64().unwrap() > 0.0);
        assert_eq!(j.get("samples").as_usize(), Some(10));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("us"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5_000_000_000.0).contains(" s"));
    }
}
