//! Dependency-free substrates: RNG, statistics, JSON, CLI parsing,
//! bench, threading pool, and the in-repo lint (`tlrs-lint`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod lint;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod wire;
