//! Dependency-free substrates: RNG, statistics, JSON, CLI parsing, bench.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod wire;
