//! Rule engine for `tlrs-lint`: six token-level checks with path-scoped
//! policies, suppression annotations, and the unsafe inventory.
//!
//! The rules (docs/INVARIANTS.md has the full rationale):
//!
//! | rule            | invariant it protects                               |
//! |-----------------|-----------------------------------------------------|
//! | `unordered-iter`| no HashMap/HashSet on result paths                  |
//! | `float-ord`     | no `partial_cmp` / float-literal `==` anywhere      |
//! | `raw-spawn`     | no raw threading outside `util/pool.rs`             |
//! | `wallclock`     | no `Instant::now`/`SystemTime` in the solver core   |
//! | `panic-path`    | no unwrap/expect/slice-index on the service path    |
//! | `unsafe-audit`  | every `unsafe` carries an adjacent `SAFETY:` comment|
//!
//! Suppression: a `lint:allow` comment — rule in parens, then a
//! `: reason` tail — trailing the offending
//! line or in the contiguous comment block directly above it. Allows
//! are counted and reported; a stale or malformed allow is itself a
//! violation (`stale-allow` / `bad-allow`).
//!
//! Code under `#[cfg(test)]` / `#[test]` is skipped: tests may unwrap,
//! time and spawn freely — the invariants guard shipped behavior.
//!
//! `python/tools/lint.py` mirrors this file; the fixture corpus under
//! `rust/tests/lint_fixtures/` pins both to identical verdicts.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, Kind, Tok};

/// The allowable rule names inside a `lint:allow` annotation.
pub const RULES: [&str; 6] = [
    "unordered-iter",
    "float-ord",
    "raw-spawn",
    "wallclock",
    "panic-path",
    "unsafe-audit",
];

/// Keywords that may legitimately precede `[` (array literals, `in [..]`)
/// — everything else before `[` on the service path is an index panic.
const RUST_KEYWORDS: [&str; 30] = [
    "let", "mut", "ref", "in", "as", "return", "break", "continue", "move",
    "if", "else", "match", "for", "while", "loop", "where", "dyn", "box",
    "yield", "const", "static", "fn", "impl", "pub", "use", "mod", "enum",
    "struct", "trait", "type",
];

const UNWRAP_LIKE: [&str; 2] = ["unwrap", "expect"];
const SPAWN_LIKE: [&str; 3] = ["spawn", "scope", "Builder"];

const R1_PREFIXES: [&str; 7] =
    ["algo/", "lp/", "model/", "io/", "sim/", "runtime/", "harness/"];
const R1_FILES: [&str; 4] = [
    "util/wire.rs", "util/json.rs",
    "coordinator/service.rs", "coordinator/session.rs",
];
const R4_EXEMPT_FILES: [&str; 6] = [
    "coordinator/metrics.rs", "coordinator/runtime.rs",
    "coordinator/session.rs", "coordinator/planner.rs",
    "util/bench.rs", "main.rs",
];
const R4_EXEMPT_PREFIXES: [&str; 2] = ["harness/", "bin/"];
const R5_FILES: [&str; 2] = ["coordinator/service.rs", "util/wire.rs"];
const R5_INDEX_FILES: [&str; 1] = ["coordinator/service.rs"];
const R3_EXEMPT_FILES: [&str; 1] = ["util/pool.rs"];

fn r1_applies(path: &str) -> bool {
    R1_PREFIXES.iter().any(|p| path.starts_with(p)) || R1_FILES.contains(&path)
}

fn r3_applies(path: &str) -> bool {
    !R3_EXEMPT_FILES.contains(&path)
}

fn r4_applies(path: &str) -> bool {
    !R4_EXEMPT_FILES.contains(&path)
        && !R4_EXEMPT_PREFIXES.iter().any(|p| path.starts_with(p))
}

fn r5_applies(path: &str) -> bool {
    R5_FILES.contains(&path)
}

fn r5_index_applies(path: &str) -> bool {
    R5_INDEX_FILES.contains(&path)
}

/// One reported violation: (line, rule, message).
pub type Finding = (usize, String, String);
/// One honored suppression: (line, rule, reason).
pub type AllowUse = (usize, String, String);
/// One inventoried unsafe block: (line, safety comment, allow reason).
pub type UnsafeBlock = (usize, Option<String>, Option<String>);

/// Result of scanning one file.
pub struct ScanOut {
    pub findings: Vec<Finding>,
    pub allows_used: Vec<AllowUse>,
    pub unsafe_blocks: Vec<UnsafeBlock>,
}

/// Strip comment sigils so only the prose lands in the inventory.
fn clean_comment(text: &str) -> String {
    let mut t = text.trim();
    if let Some(stripped) = t.strip_prefix("/*") {
        t = stripped;
        if let Some(stripped) = t.strip_suffix("*/") {
            t = stripped;
        }
    }
    while let Some(stripped) = t.strip_prefix('/') {
        t = stripped;
    }
    if let Some(stripped) = t.strip_prefix('!') {
        t = stripped;
    }
    t.trim().to_string()
}

/// Parsed `lint:allow` annotation: `Ok((rule, reason))`, or the
/// malformation detail. `None` from [`parse_allow`] means no annotation.
type AllowParse = Result<(String, String), String>;

/// Extract a `lint:allow` annotation — rule in parens, `: reason`
/// tail — from one comment.
fn parse_allow(text: &str) -> Option<AllowParse> {
    let tag = "lint:allow(";
    let at = text.find(tag)?;
    let rest = &text[at + tag.len()..];
    let close = match rest.find(')') {
        Some(c) => c,
        None => return Some(Err("unclosed lint:allow annotation".into())),
    };
    let rule = rest[..close].trim();
    let tail = &rest[close + 1..];
    let reason = match tail.strip_prefix(':') {
        Some(r) => r.trim(),
        None => return Some(Err("lint:allow needs `): reason`".into())),
    };
    if !RULES.contains(&rule) {
        return Some(Err(format!("unknown rule `{rule}` in lint:allow")));
    }
    if reason.is_empty() {
        return Some(Err(format!("empty reason in lint:allow({rule})")));
    }
    Some(Ok((rule.to_string(), reason.to_string())))
}

/// One registered allow annotation and its use count.
struct Allow {
    line: usize,
    rule: String,
    reason: String,
    used: usize,
}

/// All per-file scanning state; [`scan_source`] drives it.
struct FileScan {
    ct: Vec<Tok>,
    skips: Vec<(usize, usize)>,
    skip_lines: BTreeSet<usize>,
    has_code: BTreeSet<usize>,
    comments: BTreeMap<usize, Vec<String>>,
    allows: Vec<Allow>,
    bad_allows: Vec<(usize, String)>,
}

impl FileScan {
    fn new(src: &str) -> FileScan {
        let toks = lex(src);
        let ct: Vec<Tok> =
            toks.iter().filter(|t| t.kind != Kind::Comment).cloned().collect();
        let skips = test_ranges(&ct);
        let mut skip_lines = BTreeSet::new();
        for &(lo, hi) in &skips {
            for ln in ct[lo].line..=ct[hi].line {
                skip_lines.insert(ln);
            }
        }
        let has_code: BTreeSet<usize> = ct.iter().map(|t| t.line).collect();
        let mut comments: BTreeMap<usize, Vec<String>> = BTreeMap::new();
        for t in &toks {
            if t.kind == Kind::Comment {
                comments.entry(t.line).or_default().push(t.text.clone());
            }
        }
        let mut allows = Vec::new();
        let mut bad_allows = Vec::new();
        for (&ln, texts) in &comments {
            for text in texts {
                match parse_allow(text) {
                    None => {}
                    Some(Err(detail)) => bad_allows.push((ln, detail)),
                    Some(Ok((rule, reason))) => {
                        allows.push(Allow { line: ln, rule, reason, used: 0 })
                    }
                }
            }
        }
        FileScan { ct, skips, skip_lines, has_code, comments, allows, bad_allows }
    }

    fn in_skip(&self, ci: usize) -> bool {
        self.skips.iter().any(|&(lo, hi)| lo <= ci && ci <= hi)
    }

    /// The comment lines an annotation suppressing `line` may live on:
    /// the line itself plus the contiguous run of comment-only lines
    /// directly above it.
    fn attached_lines(&self, line: usize) -> Vec<usize> {
        let mut out = vec![line];
        let mut ln = line.wrapping_sub(1);
        while ln > 0
            && self.comments.contains_key(&ln)
            && !self.has_code.contains(&ln)
        {
            out.push(ln);
            ln -= 1;
        }
        out
    }

    fn find_allow(&self, line: usize, rule: &str) -> Option<usize> {
        for ln in self.attached_lines(line) {
            for (ai, a) in self.allows.iter().enumerate() {
                if a.line == ln && a.rule == rule {
                    return Some(ai);
                }
            }
        }
        None
    }

    fn find_safety(&self, line: usize) -> Option<String> {
        for ln in self.attached_lines(line) {
            if let Some(texts) = self.comments.get(&ln) {
                for text in texts {
                    if text.to_lowercase().contains("safety") {
                        return Some(clean_comment(text));
                    }
                }
            }
        }
        None
    }
}

/// Token-index ranges (inclusive) of `#[cfg(test)]` / `#[test]` items
/// over the comment-free token stream.
fn test_ranges(ct: &[Tok]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let n = ct.len();
    let mut i = 0usize;
    while i < n {
        if ct[i].text == "#" && i + 1 < n && ct[i + 1].text == "[" {
            let mut j = i + 2;
            let mut depth = 1usize;
            let mut idents: Vec<&str> = Vec::new();
            while j < n && depth > 0 {
                let tx = ct[j].text.as_str();
                if tx == "[" {
                    depth += 1;
                } else if tx == "]" {
                    depth -= 1;
                } else if ct[j].kind == Kind::Ident {
                    idents.push(tx);
                }
                j += 1;
            }
            let gated = idents.contains(&"test")
                && !idents.contains(&"not")
                && (idents.len() == 1 || idents[0] == "cfg");
            if gated {
                let mut k = j;
                while k < n && ct[k].text != "{" && ct[k].text != ";" {
                    k += 1;
                }
                if k < n && ct[k].text == "{" {
                    let mut d = 1usize;
                    k += 1;
                    while k < n && d > 0 {
                        if ct[k].text == "{" {
                            d += 1;
                        } else if ct[k].text == "}" {
                            d -= 1;
                        }
                        k += 1;
                    }
                    ranges.push((i, k - 1));
                }
            }
            i = j;
        } else {
            i += 1;
        }
    }
    ranges
}

/// Lint one file. `path` is the `rust/src`-relative path with `/`
/// separators — the policy tables key off it.
pub fn scan_source(path: &str, src: &str) -> ScanOut {
    let mut fs = FileScan::new(src);
    let n = fs.ct.len();
    let mut raw: Vec<Finding> = Vec::new();
    let mut unsafe_blocks: Vec<UnsafeBlock> = Vec::new();

    let tk = |ct: &[Tok], i: isize| -> String {
        if i >= 0 && (i as usize) < ct.len() {
            ct[i as usize].text.clone()
        } else {
            String::new()
        }
    };
    let kd = |ct: &[Tok], i: isize| -> Option<Kind> {
        if i >= 0 && (i as usize) < ct.len() {
            Some(ct[i as usize].kind)
        } else {
            None
        }
    };

    for i in 0..n {
        if fs.in_skip(i) {
            continue;
        }
        let ii = i as isize;
        let (kind, text, line) = {
            let t = &fs.ct[i];
            (t.kind, t.text.clone(), t.line)
        };
        match kind {
            Kind::Ident => {
                if (text == "HashMap" || text == "HashSet") && r1_applies(path) {
                    raw.push((line, "unordered-iter".into(), format!(
                        "`{text}` on a result path: iteration order is \
                         nondeterministic — use BTreeMap/BTreeSet or \
                         drain through a sort"
                    )));
                }
                if text == "partial_cmp" {
                    raw.push((line, "float-ord".into(),
                        "`partial_cmp` on floats: use `f64::total_cmp` \
                         for a total, NaN-safe order".into()));
                }
                if text == "thread"
                    && tk(&fs.ct, ii + 1) == "::"
                    && SPAWN_LIKE.contains(&tk(&fs.ct, ii + 2).as_str())
                    && r3_applies(path)
                {
                    raw.push((line, "raw-spawn".into(), format!(
                        "`thread::{}` outside util/pool.rs: route \
                         threading through the pool primitives",
                        tk(&fs.ct, ii + 2)
                    )));
                }
                if text == "Instant"
                    && tk(&fs.ct, ii + 1) == "::"
                    && tk(&fs.ct, ii + 2) == "now"
                    && r4_applies(path)
                {
                    raw.push((line, "wallclock".into(),
                        "`Instant::now` in the solver core: wall-clock \
                         reads belong to the coordinator/harness layers".into()));
                }
                if text == "SystemTime" && r4_applies(path) {
                    raw.push((line, "wallclock".into(),
                        "`SystemTime` in the solver core: wall-clock \
                         reads belong to the coordinator/harness layers".into()));
                }
                if UNWRAP_LIKE.contains(&text.as_str())
                    && tk(&fs.ct, ii - 1) == "."
                    && tk(&fs.ct, ii + 1) == "("
                    && r5_applies(path)
                {
                    raw.push((line, "panic-path".into(), format!(
                        "`.{text}()` on the service request path: return a \
                         typed error instead"
                    )));
                }
                if text == "unsafe" {
                    let safety = fs.find_safety(line);
                    let allow = fs.find_allow(line, "unsafe-audit");
                    let allow_reason = allow.map(|ai| {
                        fs.allows[ai].used += 1;
                        fs.allows[ai].reason.clone()
                    });
                    let missing = safety.is_none();
                    unsafe_blocks.push((line, safety, allow_reason));
                    if missing {
                        raw.push((line, "unsafe-audit".into(),
                            "`unsafe` without an adjacent \
                             `// SAFETY:` comment".into()));
                    }
                }
            }
            Kind::Op => {
                if (text == "==" || text == "!=")
                    && (kd(&fs.ct, ii - 1) == Some(Kind::Fnum)
                        || kd(&fs.ct, ii + 1) == Some(Kind::Fnum))
                {
                    raw.push((line, "float-ord".into(),
                        "float literal compared with `==`/`!=`: exact \
                         float equality needs a justifying annotation".into()));
                }
                if text == "["
                    && r5_index_applies(path)
                    && ((kd(&fs.ct, ii - 1) == Some(Kind::Ident)
                        && !RUST_KEYWORDS.contains(&tk(&fs.ct, ii - 1).as_str()))
                        || tk(&fs.ct, ii - 1) == ")"
                        || tk(&fs.ct, ii - 1) == "]")
                {
                    raw.push((line, "panic-path".into(),
                        "slice index on the service request path: use \
                         `get(..)` and return a typed error".into()));
                }
            }
            _ => {}
        }
    }

    let mut findings: Vec<Finding> = Vec::new();
    for (line, rule, msg) in raw {
        if let Some(ai) = fs.find_allow(line, &rule) {
            fs.allows[ai].used += 1;
            continue;
        }
        findings.push((line, rule, msg));
    }
    // an unsafe block whose allow was consumed during the inventory pass
    // must not survive as a finding
    findings.retain(|f| {
        !(f.1 == "unsafe-audit" && fs.find_allow(f.0, "unsafe-audit").is_some())
    });

    for (ln, detail) in &fs.bad_allows {
        if !fs.skip_lines.contains(ln) {
            findings.push((*ln, "bad-allow".into(), detail.clone()));
        }
    }
    for a in &fs.allows {
        if a.used == 0 && !fs.skip_lines.contains(&a.line) {
            findings.push((a.line, "stale-allow".into(), format!(
                "allow for `{}` suppresses nothing — remove it", a.rule
            )));
        }
    }
    findings.sort();
    let allows_used: Vec<AllowUse> = fs
        .allows
        .iter()
        .filter(|a| a.used > 0)
        .map(|a| (a.line, a.rule.clone(), a.reason.clone()))
        .collect();
    ScanOut { findings, allows_used, unsafe_blocks }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(path: &str, src: &str) -> Vec<(usize, String)> {
        scan_source(path, src)
            .findings
            .into_iter()
            .map(|(ln, rule, _)| (ln, rule))
            .collect()
    }

    #[test]
    fn policy_scoping() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules_of("algo/x.rs", src).len(), 1);
        assert_eq!(rules_of("coordinator/metrics.rs", src).len(), 0);
    }

    #[test]
    fn allow_suppresses_and_is_counted() {
        let src = "// lint:allow(float-ord): exact sentinel\nif x == 1.0 {}\n";
        let out = scan_source("algo/x.rs", src);
        assert!(out.findings.is_empty());
        assert_eq!(out.allows_used.len(), 1);
    }

    #[test]
    fn stale_allow_is_a_finding() {
        let src = "// lint:allow(float-ord): nothing here\nlet x = 1;\n";
        let got = rules_of("algo/x.rs", src);
        assert_eq!(got, vec![(1, "stale-allow".to_string())]);
    }

    #[test]
    fn test_code_is_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        assert!(rules_of("coordinator/service.rs", src).is_empty());
    }

    #[test]
    fn unsafe_inventory() {
        let src = "// SAFETY: disjoint\nunsafe { ptr.read() }\nunsafe { bad() }\n";
        let out = scan_source("lp/x.rs", src);
        assert_eq!(out.unsafe_blocks.len(), 2);
        assert!(out.unsafe_blocks[0].1.is_some());
        assert!(out.unsafe_blocks[1].1.is_none());
        assert_eq!(out.findings.len(), 1);
        assert_eq!(out.findings[0].1, "unsafe-audit");
    }
}
