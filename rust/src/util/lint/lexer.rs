//! Handwritten Rust lexer for `tlrs-lint`.
//!
//! Tokenizes Rust source into (kind, text, line) triples. Comments are
//! kept as tokens (the rule passes need them to find `// SAFETY:` and
//! `lint:allow` annotations); strings, chars and lifetimes are
//! consumed precisely so braces and quotes inside them can never
//! confuse the rule passes. No type information, no syn — the rules in
//! [`super::rules`] are all expressible over this token stream.
//!
//! `python/tools/lint.py` mirrors this file function for function; the
//! shared fixture corpus under `rust/tests/lint_fixtures/` pins the two
//! implementations to identical verdicts.

/// Token kind. `Fnum` is split out from `Num` because the `float-ord`
/// rule fires on `==`/`!=` adjacent to a *float* literal only.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Ident,
    Num,
    Fnum,
    Str,
    Char,
    Life,
    Op,
    Comment,
}

/// One lexed token: kind, verbatim text, 1-based line of its first char.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

const OPS3: [&str; 4] = ["<<=", ">>=", "..=", "..."];
const OPS2: [&str; 20] = [
    "::", "==", "!=", "<=", ">=", "&&", "||", "->", "=>", "..",
    "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
];

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn text_of(s: &[char], i: usize, j: usize) -> String {
    s[i..j].iter().collect()
}

/// True when `s[j..]` starts with the char sequence `pat`.
fn starts_with_at(s: &[char], j: usize, pat: &[char]) -> bool {
    j + pat.len() <= s.len() && s[j..j + pat.len()] == *pat
}

/// Tokenize Rust source. The lexer never fails: unrecognized bytes
/// become single-char `Op` tokens, unterminated literals run to EOF.
pub fn lex(src: &str) -> Vec<Tok> {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Comment, text: text_of(&s, i, j), line });
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let start = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if s[j] == '/' && j + 1 < n && s[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if s[j] == '*' && j + 1 < n && s[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if s[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            toks.push(Tok { kind: Kind::Comment, text: text_of(&s, i, j), line: start });
            i = j;
            continue;
        }
        // raw / byte string prefixes and raw identifiers
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            if c == 'b' && j < n && s[j] == 'r' {
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && s[j] == '#' {
                hashes += 1;
                j += 1;
            }
            // r".., r#".., br".." are raw; b".." is NOT (it has escapes)
            let raw_form = j > i + 1 || c == 'r';
            if j < n && s[j] == '"' && raw_form {
                // raw (byte) string — no escapes, runs to `"` + hashes
                j += 1;
                let mut close = vec!['"'];
                close.extend(std::iter::repeat('#').take(hashes));
                let start = line;
                while j < n && !starts_with_at(&s, j, &close) {
                    if s[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                j += close.len();
                let j = j.min(n);
                toks.push(Tok { kind: Kind::Str, text: text_of(&s, i, j), line: start });
                i = j;
                continue;
            }
            if c == 'r' && hashes == 1 && j < n && is_ident_start(s[j]) {
                // raw identifier r#type
                let mut k = j;
                while k < n && is_ident_cont(s[k]) {
                    k += 1;
                }
                toks.push(Tok { kind: Kind::Ident, text: text_of(&s, j, k), line });
                i = k;
                continue;
            }
            if c == 'b' && i + 1 < n && s[i + 1] == '"' {
                let (i2, line2) = lex_quoted(&s, i + 1, line);
                toks.push(Tok { kind: Kind::Str, text: text_of(&s, i, i2), line });
                i = i2;
                line = line2;
                continue;
            }
            if c == 'b' && i + 1 < n && s[i + 1] == '\'' {
                let i2 = lex_char(&s, i + 1);
                toks.push(Tok { kind: Kind::Char, text: text_of(&s, i, i2), line });
                i = i2;
                continue;
            }
            // otherwise: a plain identifier starting with r/b — fall through
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(s[j]) {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: text_of(&s, i, j), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let (i2, is_float) = lex_number(&s, i);
            let kind = if is_float { Kind::Fnum } else { Kind::Num };
            toks.push(Tok { kind, text: text_of(&s, i, i2), line });
            i = i2;
            continue;
        }
        if c == '"' {
            let (i2, line2) = lex_quoted(&s, i, line);
            toks.push(Tok { kind: Kind::Str, text: text_of(&s, i, i2), line });
            i = i2;
            line = line2;
            continue;
        }
        if c == '\'' {
            if i + 1 < n && s[i + 1] == '\\' {
                let i2 = lex_char(&s, i);
                toks.push(Tok { kind: Kind::Char, text: text_of(&s, i, i2), line });
                i = i2;
                continue;
            }
            if i + 2 < n && is_ident_start(s[i + 1]) && s[i + 2] != '\'' {
                // lifetime 'a / 'static
                let mut j = i + 1;
                while j < n && is_ident_cont(s[j]) {
                    j += 1;
                }
                toks.push(Tok { kind: Kind::Life, text: text_of(&s, i, j), line });
                i = j;
                continue;
            }
            let i2 = lex_char(&s, i);
            toks.push(Tok { kind: Kind::Char, text: text_of(&s, i, i2), line });
            i = i2;
            continue;
        }
        if i + 3 <= n {
            let three = text_of(&s, i, i + 3);
            if OPS3.contains(&three.as_str()) {
                toks.push(Tok { kind: Kind::Op, text: three, line });
                i += 3;
                continue;
            }
        }
        if i + 2 <= n {
            let two = text_of(&s, i, i + 2);
            if OPS2.contains(&two.as_str()) {
                toks.push(Tok { kind: Kind::Op, text: two, line });
                i += 2;
                continue;
            }
        }
        toks.push(Tok { kind: Kind::Op, text: c.to_string(), line });
        i += 1;
    }
    toks
}

/// Consume a normal `"..."` string starting at the quote; returns
/// (end index, line after the string).
fn lex_quoted(s: &[char], i: usize, mut line: usize) -> (usize, usize) {
    let n = s.len();
    let mut j = i + 1;
    while j < n {
        if s[j] == '\\' {
            // an escaped newline (line continuation) still ends a line
            if j + 1 < n && s[j + 1] == '\n' {
                line += 1;
            }
            j += 2;
            continue;
        }
        if s[j] == '\n' {
            line += 1;
        }
        if s[j] == '"' {
            return (j + 1, line);
        }
        j += 1;
    }
    (j.min(n), line)
}

/// Consume a `'x'` / `'\n'` char literal starting at the quote.
fn lex_char(s: &[char], i: usize) -> usize {
    let n = s.len();
    let mut j = i + 1;
    while j < n {
        if s[j] == '\\' {
            j += 2;
            continue;
        }
        if s[j] == '\'' {
            return j + 1;
        }
        j += 1;
    }
    j.min(n)
}

/// Consume a numeric literal; returns (end index, is_float).
fn lex_number(s: &[char], i: usize) -> (usize, bool) {
    let n = s.len();
    let mut j = i;
    if s[j] == '0' && j + 1 < n && matches!(s[j + 1], 'x' | 'o' | 'b') {
        j += 2;
        while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
            j += 1;
        }
        return (j, false);
    }
    let mut is_float = false;
    while j < n && (s[j].is_ascii_digit() || s[j] == '_') {
        j += 1;
    }
    if j < n && s[j] == '.' {
        let nxt = if j + 1 < n { s[j + 1] } else { '\0' };
        if nxt.is_ascii_digit() {
            is_float = true;
            j += 1;
            while j < n && (s[j].is_ascii_digit() || s[j] == '_') {
                j += 1;
            }
        } else if nxt != '.' && !is_ident_start(nxt) {
            // trailing-dot float like `1.`
            is_float = true;
            j += 1;
        }
    }
    if j < n && matches!(s[j], 'e' | 'E') {
        let mut k = j + 1;
        if k < n && matches!(s[k], '+' | '-') {
            k += 1;
        }
        if k < n && s[k].is_ascii_digit() {
            is_float = true;
            j = k;
            while j < n && (s[j].is_ascii_digit() || s[j] == '_') {
                j += 1;
            }
        }
    }
    // type suffix (1usize, 2.5f64, 1f32)
    if j < n && is_ident_start(s[j]) {
        if s[j] == 'f' {
            is_float = true;
        }
        while j < n && is_ident_cont(s[j]) {
            j += 1;
        }
    }
    (j, is_float)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn basic_tokens() {
        let t = kinds("let x = 1.5 + y;");
        assert_eq!(
            t,
            vec![
                (Kind::Ident, "let".to_string()),
                (Kind::Ident, "x".to_string()),
                (Kind::Op, "=".to_string()),
                (Kind::Fnum, "1.5".to_string()),
                (Kind::Op, "+".to_string()),
                (Kind::Ident, "y".to_string()),
                (Kind::Op, ";".to_string()),
            ]
        );
    }

    #[test]
    fn float_forms() {
        for (src, want) in [
            ("1.0", Kind::Fnum),
            ("1.", Kind::Fnum),
            ("1e3", Kind::Fnum),
            ("2f64", Kind::Fnum),
            ("1_000", Kind::Num),
            ("0xff", Kind::Num),
            ("3usize", Kind::Num),
        ] {
            assert_eq!(lex(src)[0].kind, want, "{src}");
        }
        // `1..n` is a range, not a float
        let t = kinds("1..n");
        assert_eq!(t[0], (Kind::Num, "1".to_string()));
        assert_eq!(t[1], (Kind::Op, "..".to_string()));
    }

    #[test]
    fn strings_hide_contents() {
        let t = kinds(r#"let s = "HashMap == 1.0"; x"#);
        assert!(t.iter().all(|(k, tx)| *k != Kind::Ident || tx != "HashMap"));
        let t = kinds("r#\"unsafe \" inside\"# y");
        assert_eq!(t[0].0, Kind::Str);
        assert_eq!(t[1], (Kind::Ident, "y".to_string()));
    }

    #[test]
    fn line_numbers_cross_multiline_tokens() {
        let src = "a\n/* x\n y */\n\"s1\\\n s2\"\nb";
        let t = lex(src);
        let b = t.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 6);
    }

    #[test]
    fn lifetimes_and_chars() {
        let t = kinds("&'a str; 'x'; '\\n'");
        assert_eq!(t[1], (Kind::Life, "'a".to_string()));
        assert!(t.iter().any(|(k, tx)| *k == Kind::Char && tx == "'x'"));
    }
}
