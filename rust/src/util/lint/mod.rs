//! `tlrs-lint`: in-repo determinism & safety analyzer.
//!
//! The solver's headline guarantees — bit-identical parallel solves,
//! seed-reproducible workloads, a service that degrades instead of
//! dying — rest on coding invariants no compiler checks: no unordered
//! iteration on result paths, no partial float orders, all threading
//! through `util::pool`, no wall-clock reads in the solver core, no
//! panics on the service request path, every `unsafe` audited. This
//! module enforces them at the token level over the crate's own
//! sources; `src/bin/lint.rs` is the CLI and `scripts/lint.sh` the
//! gate entry point.
//!
//! Zero dependencies by design: [`lexer`] is a handwritten Rust lexer
//! in the house style of `util::json` / `util::wire`, and [`rules`] is
//! a small token-pattern engine over it. `python/tools/lint.py`
//! mirrors both line for line so the gate runs in toolchain-less
//! containers; `rust/tests/lint_fixtures/` pins the two to identical
//! verdicts.

pub mod lexer;
pub mod rules;

pub use rules::{scan_source, Finding, ScanOut, RULES};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Aggregated result of scanning a source tree. All vectors are sorted
/// (file, line, ..) so output is deterministic and diffable; the Python
/// mirror produces the identical ordering.
pub struct TreeReport {
    pub n_files: usize,
    /// (file, line, rule, message)
    pub findings: Vec<(String, usize, String, String)>,
    /// (file, line, rule, reason)
    pub allows: Vec<(String, usize, String, String)>,
    /// (file, line, safety comment, allow reason)
    pub blocks: Vec<(String, usize, Option<String>, Option<String>)>,
}

/// All `.rs` files under `root`, as sorted root-relative `/`-paths.
pub fn walk_rs(root: &Path) -> io::Result<Vec<String>> {
    fn visit(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
        let mut entries: Vec<_> =
            fs::read_dir(dir)?.collect::<Result<_, _>>()?;
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let p = e.path();
            if p.is_dir() {
                visit(&p, out)?;
            } else if p.extension().map_or(false, |x| x == "rs") {
                out.push(p);
            }
        }
        Ok(())
    }
    let mut full = Vec::new();
    visit(root, &mut full)?;
    let mut out: Vec<String> = full
        .iter()
        .map(|p| {
            p.strip_prefix(root)
                .expect("walked path is under root")
                .to_string_lossy()
                .replace(std::path::MAIN_SEPARATOR, "/")
        })
        .collect();
    out.sort();
    Ok(out)
}

/// Scan every `.rs` file under `root` and merge the per-file results.
pub fn scan_tree(root: &Path) -> io::Result<TreeReport> {
    let files = walk_rs(root)?;
    let mut findings = Vec::new();
    let mut allows = Vec::new();
    let mut blocks = Vec::new();
    for rel in &files {
        let src = fs::read_to_string(root.join(rel))?;
        let out = scan_source(rel, &src);
        for (ln, rule, msg) in out.findings {
            findings.push((rel.clone(), ln, rule, msg));
        }
        for (ln, rule, reason) in out.allows_used {
            allows.push((rel.clone(), ln, rule, reason));
        }
        for (ln, safety, reason) in out.unsafe_blocks {
            blocks.push((rel.clone(), ln, safety, reason));
        }
    }
    findings.sort();
    allows.sort();
    blocks.sort_by(|a, b| (&a.0, a.1).cmp(&(&b.0, b.1)));
    Ok(TreeReport { n_files: files.len(), findings, allows, blocks })
}

/// Minimal JSON string escaper — same table as the Python mirror.
pub fn json_escape(s: &str) -> String {
    let mut out = String::new();
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

/// Render the unsafe inventory (`LINT_unsafe.json`). Byte-identical to
/// the Python mirror's output on the same blocks.
pub fn unsafe_json(
    blocks: &[(String, usize, Option<String>, Option<String>)],
) -> String {
    let mut lines = vec![
        "{".to_string(),
        format!("  \"total\": {},", blocks.len()),
        "  \"blocks\": [".to_string(),
    ];
    for (i, (f, ln, safety, allow)) in blocks.iter().enumerate() {
        let s = match safety {
            None => "null".to_string(),
            Some(t) => format!("\"{}\"", json_escape(t)),
        };
        let a = match allow {
            None => "null".to_string(),
            Some(t) => format!("\"{}\"", json_escape(t)),
        };
        let comma = if i + 1 < blocks.len() { "," } else { "" };
        lines.push(format!(
            "    {{\"file\": \"{}\", \"line\": {}, \"safety\": {}, \"allow\": {}}}{}",
            json_escape(f), ln, s, a, comma
        ));
    }
    lines.push("  ]".to_string());
    lines.push("}".to_string());
    lines.join("\n") + "\n"
}
