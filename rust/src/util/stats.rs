//! Small statistics helpers shared by the harness and benches.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for len < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Index of the first minimum under the NaN-safe total order — the
/// single first-wins selection rule the pipeline candidate fold, the
/// portfolio racer and the evaluation layers all share (their
/// determinism contract requires them to agree on tie direction).
pub fn argmin_f64(xs: impl IntoIterator<Item = f64>) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, x) in xs.into_iter().enumerate() {
        let better = match best {
            None => true,
            Some((_, b)) => x.total_cmp(&b) == std::cmp::Ordering::Less,
        };
        if better {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Summary record used by the harness report tables.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        Summary {
            mean: mean(xs),
            std: stddev(xs),
            min: if xs.is_empty() { 0.0 } else { min(xs) },
            max: if xs.is_empty() { 0.0 } else { max(xs) },
            n: xs.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138).abs() < 1e-3);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 3.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.n, 2);
    }

    #[test]
    fn argmin_first_wins_and_nan_safe() {
        assert_eq!(argmin_f64([3.0, 1.0, 2.0]), Some(1));
        // ties keep the earliest index — the determinism contract
        assert_eq!(argmin_f64([2.0, 1.0, 1.0, 5.0]), Some(1));
        assert_eq!(argmin_f64(std::iter::empty::<f64>()), None);
        // NaN orders greatest under total_cmp, never masking a real min
        assert_eq!(argmin_f64([f64::NAN, 4.0, 4.0]), Some(1));
    }
}
