//! Worker pools, three shapes for three lifetimes:
//!
//!   * [`run_indexed`] — scoped-thread fan-out over a *finite* job list
//!     (ticket counter + slot mutex + `thread::scope`), returning results
//!     in index order. The portfolio racer and the planner's sweep pool
//!     run on it; scoped borrowing of the caller's data is its point.
//!   * [`Team`] — persistent parked helpers for *kernel-grained* scoped
//!     work: [`Team::run_blocks`] dispatches one block-indexed closure
//!     borrowing the caller's stack and returns when every block ran.
//!     Spawning scoped threads per call (as `run_indexed` does) costs
//!     tens of microseconds; the LP engine dispatches its operator
//!     kernels hundreds of thousands of times per solve, so the team
//!     wakes parked threads instead.
//!   * [`WorkerPool`] — a *long-lived* pool with a bounded job queue for
//!     the service runtime: jobs are `'static` closures, submission is
//!     non-blocking admission control ([`WorkerPool::try_submit`] hands
//!     the job back instead of queueing unboundedly — the caller decides
//!     how to shed), and [`WorkerPool::shutdown`] drains every queued job
//!     before joining the workers (graceful shutdown).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Run `f(i)` for every index in `0..n` on at most `workers` scoped
/// threads and return the results in index order. Work is distributed
/// by an atomic ticket counter; output order (and therefore every
/// downstream index tie-break) is independent of scheduling.
pub fn run_indexed<R, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = std::sync::Mutex::new(&mut results);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let r = f(i);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    results.into_iter().map(|r| r.expect("worker completed")).collect()
}

// ----- persistent scoped team ----------------------------------------------

/// Type-erased description of one [`Team::run_blocks`] dispatch. The raw
/// pointers reference the caller's stack frame; they stay valid because
/// `run_blocks` does not return until every helper has left the
/// generation (`running == 0`), so the borrow strictly outlives every
/// use.
#[derive(Clone, Copy)]
struct BlockJob {
    f: *const (dyn Fn(usize) + Sync),
    next: *const AtomicUsize,
    n: usize,
}

// Safety: the closure behind `f` is `Sync` (shared access from many
// threads is its contract) and the pointers are only dereferenced while
// the owning `run_blocks` frame is blocked alive (see `BlockJob` doc).
unsafe impl Send for BlockJob {}

struct TeamCtrl {
    /// Bumped once per dispatch; helpers compare against the generation
    /// they last served to detect new work.
    generation: u64,
    job: Option<BlockJob>,
    /// Helpers still inside the current generation.
    running: usize,
    /// A helper's block panicked this generation.
    panicked: bool,
    shutdown: bool,
}

struct TeamShared {
    ctrl: Mutex<TeamCtrl>,
    start: Condvar,
    done: Condvar,
}

/// A persistent team of parked threads for scoped data-parallel kernels.
///
/// [`Team::run_blocks`] runs `f(block)` for every block in
/// `0..n_blocks`, on the calling thread plus `threads - 1` parked
/// helpers, and returns once all blocks finished — which is exactly what
/// makes lending the helpers a non-`'static` closure sound: the borrow
/// cannot outlive the call. Blocks are claimed from an atomic ticket
/// counter, so *which thread* runs a block is scheduling-dependent;
/// callers needing deterministic results must make blocks independent
/// (disjoint writes) and do any cross-block combining themselves in
/// fixed block order after the call.
///
/// A panic inside a block is re-raised from `run_blocks` after the whole
/// team has quiesced; the team stays usable.
pub struct Team {
    shared: Arc<TeamShared>,
    /// Serializes concurrent `run_blocks` callers (the control slot
    /// holds one dispatch at a time).
    run_lock: Mutex<()>,
    threads: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Team {
    /// A team of `threads` total threads: the caller participates, so
    /// only `threads - 1` helpers are spawned. `threads <= 1` spawns
    /// nothing and `run_blocks` degenerates to an inline loop — the
    /// zero-overhead sequential path.
    pub fn new(threads: usize) -> Team {
        let threads = threads.max(1);
        let shared = Arc::new(TeamShared {
            ctrl: Mutex::new(TeamCtrl {
                generation: 0,
                job: None,
                running: 0,
                panicked: false,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("team-{i}"))
                    .spawn(move || team_helper_loop(&shared))
                    .expect("spawn team helper")
            })
            .collect();
        Team { shared, run_lock: Mutex::new(()), threads, handles }
    }

    /// Total thread count (caller + helpers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(block)` for every block in `0..n_blocks` across the team,
    /// returning when all blocks completed. See the type doc for the
    /// determinism contract.
    pub fn run_blocks<F>(&self, n_blocks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.handles.is_empty() || n_blocks <= 1 {
            for b in 0..n_blocks {
                f(b);
            }
            return;
        }
        let serial = self.run_lock.lock().unwrap();
        let next = AtomicUsize::new(0);
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: this erases the closure's lifetime for the helpers.
        // Sound because this frame blocks below until `running == 0`,
        // i.e. until no helper can still reach the pointer (see
        // `BlockJob`); the debug_assert under the ctrl lock pins the
        // no-job-in-flight precondition before the pointer is published.
        let f_static: &'static (dyn Fn(usize) + Sync) =
            // SAFETY: see the contract above — the frame outlives helpers.
            unsafe { std::mem::transmute(f_ref) };
        {
            let mut ctrl = self.shared.ctrl.lock().unwrap();
            debug_assert!(ctrl.job.is_none() && ctrl.running == 0);
            ctrl.generation += 1;
            ctrl.job = Some(BlockJob { f: f_static, next: &next, n: n_blocks });
            ctrl.running = self.handles.len();
            ctrl.panicked = false;
        }
        self.shared.start.notify_all();
        // participate — the calling thread is a team member too; catch a
        // local panic so the helpers still quiesce before we unwind past
        // the borrowed closure
        let mine = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            drain_tickets(f_ref, &next, n_blocks)
        }));
        let helper_panicked = {
            let mut ctrl = self.shared.ctrl.lock().unwrap();
            while ctrl.running > 0 {
                ctrl = self.shared.done.wait(ctrl).unwrap();
            }
            ctrl.job = None;
            ctrl.panicked
        };
        drop(serial);
        if let Err(p) = mine {
            std::panic::resume_unwind(p);
        }
        if helper_panicked {
            panic!("team: a parallel block panicked");
        }
    }
}

impl Drop for Team {
    fn drop(&mut self) {
        self.shared.ctrl.lock().unwrap().shutdown = true;
        self.shared.start.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn drain_tickets(f: &(dyn Fn(usize) + Sync), next: &AtomicUsize, n: usize) {
    loop {
        let b = next.fetch_add(1, Ordering::SeqCst);
        if b >= n {
            break;
        }
        f(b);
    }
}

fn team_helper_loop(shared: &TeamShared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut ctrl = shared.ctrl.lock().unwrap();
            loop {
                if ctrl.shutdown {
                    return;
                }
                if ctrl.generation != seen {
                    seen = ctrl.generation;
                    break ctrl.job.expect("generation bumped with a job set");
                }
                ctrl = shared.start.wait(ctrl).unwrap();
            }
        };
        // SAFETY: `job`'s raw pointers reference the dispatching
        // `run_blocks` frame, which cannot return until this helper
        // decrements `running` below — the borrow strictly outlives
        // every dereference here.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            drain_tickets(&*job.f, &*job.next, job.n)
        }));
        let mut ctrl = shared.ctrl.lock().unwrap();
        if res.is_err() {
            ctrl.panicked = true;
        }
        ctrl.running -= 1;
        if ctrl.running == 0 {
            shared.done.notify_all();
        }
    }
}

// ----- long-lived bounded pool ---------------------------------------------

/// A queued unit of work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    jobs: VecDeque<Job>,
    /// Jobs currently executing on a worker.
    active: usize,
    closed: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_ready: Condvar,
}

/// Long-lived worker pool with a bounded job queue.
///
/// Admission rule: a job is accepted iff `active + queued <
/// workers + queue_cap` — so `workers = 1, queue_cap = 0` admits a job
/// only when the pool is completely idle, degenerating to strictly
/// sequential execution. [`WorkerPool::try_submit`] never blocks; it
/// hands a rejected job back so the submitter can shed load explicitly.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: usize,
    queue_cap: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` (min 1) named `<label>-<i>` threads sharing a
    /// queue that admits up to `queue_cap` jobs beyond the running ones.
    pub fn new(label: &str, workers: usize, queue_cap: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                jobs: VecDeque::new(),
                active: 0,
                closed: false,
            }),
            work_ready: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("{label}-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, workers, queue_cap, handles }
    }

    /// Admit `job` if the pool has space (see the admission rule above);
    /// hand it back otherwise. Never blocks.
    pub fn try_submit(&self, job: Job) -> Result<(), Job> {
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.closed || st.active + st.jobs.len() >= self.workers + self.queue_cap {
                return Err(job);
            }
            st.jobs.push_back(job);
        }
        self.shared.work_ready.notify_one();
        Ok(())
    }

    /// Whether a `try_submit` right now would be admitted.
    pub fn has_space(&self) -> bool {
        let st = self.shared.state.lock().unwrap();
        !st.closed && st.active + st.jobs.len() < self.workers + self.queue_cap
    }

    /// Jobs waiting in the queue (not yet picked up by a worker).
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().jobs.len()
    }

    /// Jobs currently executing.
    pub fn active(&self) -> usize {
        self.shared.state.lock().unwrap().active
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Close the queue and join every worker. Jobs already queued are
    /// drained — run to completion — before the workers exit; only
    /// *new* submissions are refused. Idempotent.
    pub fn shutdown(&mut self) {
        self.shared.state.lock().unwrap().closed = true;
        self.shared.work_ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.jobs.pop_front() {
                    st.active += 1;
                    break job;
                }
                if st.closed {
                    return;
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        // a panicking job must not kill the worker: the pool is the
        // service's whole capacity, and each lost thread would silently
        // shrink it until the server wedges
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        if res.is_err() {
            eprintln!("worker: job panicked (worker kept alive)");
        }
        shared.state.lock().unwrap().active -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;

    #[test]
    fn results_keep_index_order() {
        let out = run_indexed(17, 4, |i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        assert_eq!(run_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(1, 0, |i| i + 1), vec![1]);
        assert_eq!(run_indexed(3, 64, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn team_runs_every_block_exactly_once_across_dispatches() {
        let team = Team::new(4);
        assert_eq!(team.threads(), 4);
        // reuse the same team for several dispatches of varying size
        // (the generation counter must isolate them)
        for n in [0usize, 1, 3, 7, 64, 257] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            team.run_blocks(n, |b| {
                hits[b].fetch_add(1, Ordering::SeqCst);
            });
            for (b, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "block {b} of {n}");
            }
        }
    }

    #[test]
    fn team_of_one_is_inline() {
        let team = Team::new(1);
        assert_eq!(team.threads(), 1);
        // borrows a stack-local mutably-written-through-atomics value;
        // with one thread this never leaves the calling thread
        let sum = AtomicUsize::new(0);
        team.run_blocks(10, |b| {
            sum.fetch_add(b, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn team_blocks_borrow_caller_data() {
        // disjoint per-block regions of a caller-owned Vec, written via
        // raw parts — the pattern the LP kernels use
        let team = Team::new(3);
        let mut out = vec![0usize; 100];
        let ptr = out.as_mut_ptr() as usize;
        team.run_blocks(10, |b| {
            let p = ptr as *mut usize;
            for i in b * 10..(b + 1) * 10 {
                // Safety: block b exclusively owns out[b*10..(b+1)*10]
                unsafe { *p.add(i) = i * 2 };
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 2);
        }
    }

    #[test]
    fn team_panicking_block_propagates_and_team_survives() {
        let team = Team::new(4);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            team.run_blocks(16, |b| {
                if b == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "panic must propagate out of run_blocks");
        // the team must still dispatch correctly afterwards
        let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        team.run_blocks(8, |b| {
            hits[b].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    /// Hold `n` jobs inside the pool (blocked on a channel) and return
    /// the release sender once all of them have started.
    fn hold_jobs(pool: &WorkerPool, n: usize) -> mpsc::Sender<()> {
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let release_rx = Arc::new(Mutex::new(release_rx));
        let (started_tx, started_rx) = mpsc::channel::<()>();
        for _ in 0..n {
            let release_rx = release_rx.clone();
            let started_tx = started_tx.clone();
            pool.try_submit(Box::new(move || {
                started_tx.send(()).unwrap();
                release_rx.lock().unwrap().recv().unwrap();
            }))
            .unwrap_or_else(|_| panic!("job rejected"));
        }
        for _ in 0..n {
            started_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        release_tx
    }

    #[test]
    fn admission_bound_is_workers_plus_queue() {
        let pool = WorkerPool::new("t", 2, 1);
        // occupy both workers, then fill the single queue slot
        let release = hold_jobs(&pool, 2);
        assert_eq!(pool.active(), 2);
        assert!(pool.has_space());
        pool.try_submit(Box::new(|| {})).map_err(|_| ()).unwrap();
        assert_eq!(pool.queued(), 1);
        // 2 active + 1 queued = workers + queue_cap: full
        assert!(!pool.has_space());
        assert!(pool.try_submit(Box::new(|| {})).is_err());
        // release the held jobs; the queued one drains and space returns
        release.send(()).unwrap();
        release.send(()).unwrap();
        let t0 = std::time::Instant::now();
        while (pool.active() > 0 || pool.queued() > 0)
            && t0.elapsed() < std::time::Duration::from_secs(5)
        {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(pool.queued(), 0);
        assert!(pool.has_space());
    }

    #[test]
    fn workers_1_queue_0_is_strictly_sequential() {
        let pool = WorkerPool::new("t", 1, 0);
        let release = hold_jobs(&pool, 1);
        // anything in flight ⇒ no admission: the `--workers 1 --queue 0`
        // byte-identity configuration never runs two requests at once
        assert!(!pool.has_space());
        assert!(pool.try_submit(Box::new(|| {})).is_err());
        release.send(()).unwrap();
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        let mut pool = WorkerPool::new("t", 1, 8);
        let done = Arc::new(AtomicUsize::new(0));
        let release = hold_jobs(&pool, 1);
        for _ in 0..5 {
            let done = done.clone();
            pool.try_submit(Box::new(move || {
                done.fetch_add(1, Ordering::SeqCst);
            }))
            .unwrap_or_else(|_| panic!("queue has space"));
        }
        assert_eq!(pool.queued(), 5);
        release.send(()).unwrap();
        pool.shutdown(); // joins only after the 5 queued jobs ran
        assert_eq!(done.load(Ordering::SeqCst), 5);
        // closed pool refuses new work
        assert!(pool.try_submit(Box::new(|| {})).is_err());
        assert!(!pool.has_space());
    }

    #[test]
    fn panicking_job_does_not_kill_the_worker() {
        let mut pool = WorkerPool::new("t", 1, 2);
        pool.try_submit(Box::new(|| panic!("boom")))
            .unwrap_or_else(|_| panic!("queue has space"));
        let done = Arc::new(AtomicUsize::new(0));
        let d = done.clone();
        pool.try_submit(Box::new(move || {
            d.fetch_add(1, Ordering::SeqCst);
        }))
        .unwrap_or_else(|_| panic!("queue has space"));
        pool.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 1, "worker survived the panic");
    }
}
