//! Scoped-thread worker pool: index-ordered fan-out over a job list.
//! One subtle concurrency pattern (ticket counter + slot mutex +
//! `thread::scope`), one home — the portfolio racer and the planner's
//! sweep pool both run on it.

/// Run `f(i)` for every index in `0..n` on at most `workers` scoped
/// threads and return the results in index order. Work is distributed
/// by an atomic ticket counter; output order (and therefore every
/// downstream index tie-break) is independent of scheduling.
pub fn run_indexed<R, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = std::sync::Mutex::new(&mut results);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let r = f(i);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    results.into_iter().map(|r| r.expect("worker completed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_keep_index_order() {
        let out = run_indexed(17, 4, |i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        assert_eq!(run_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(1, 0, |i| i + 1), vec![1]);
        assert_eq!(run_indexed(3, 64, |i| i), vec![0, 1, 2]);
    }
}
