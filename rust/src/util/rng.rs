//! Deterministic PRNG (xoshiro256**) — no external crates, reproducible
//! across platforms. Every experiment seed in the harness flows through
//! this generator so paper figures regenerate bit-identically.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small consecutive seeds give
    /// well-separated states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [a, b).
    #[inline]
    pub fn uniform(&mut self, a: f64, b: f64) -> f64 {
        a + (b - a) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [a, b] inclusive.
    pub fn int_inclusive(&mut self, a: u64, b: u64) -> u64 {
        assert!(a <= b);
        a + self.below(b - a + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given log-space mean/std.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Sample k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.uniform(0.2, 1.0);
            assert!((0.2..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn int_inclusive_hits_bounds() {
        let mut r = Rng::new(11);
        let (mut lo, mut hi) = (false, false);
        for _ in 0..1000 {
            match r.int_inclusive(3, 5) {
                3 => lo = true,
                5 => hi = true,
                4 => {}
                x => panic!("out of range {x}"),
            }
        }
        assert!(lo && hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
        assert!(t.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut s = xs.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
