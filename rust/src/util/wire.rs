//! The hot tier of the two-tier wire layer: a zero-copy streaming JSON
//! pull parser and a direct-write serializer.
//!
//! `util::json` is the cold tier — a full DOM of `BTreeMap`s and heap
//! `String`s, kept for cold shapes (manifests, configs, workload specs)
//! and as the canonical semantics. This module is the hot tier the
//! service runs on:
//!
//!   * [`JsonPull`] — a non-recursive pull parser over `&[u8]` yielding
//!     [`Event`]s with zero-copy `&str` slices whenever a string
//!     contains no escapes. Typed decoders (`io::files::
//!     instance_from_slice`, `io::delta::delta_from_slice`, the service
//!     request envelope) consume the events straight into
//!     `Task`/`Delta`/`Instance` without materializing a tree.
//!   * [`JsonWriter`] / [`JsonWrite`] — a serializer that writes JSON
//!     straight into an `impl io::Write` buffer with the exact float
//!     and escape formatting of `Json::to_string`, used by
//!     `coordinator::service` for every response.
//!
//! **Equivalence contract.** The pull parser accepts exactly the
//! language `json::parse` accepts and reports the *same error message
//! at the same byte position* on malformed input; the writer emits the
//! same bytes the DOM writer emits (object keys must be fed in sorted
//! order — debug-asserted — because `Json::Obj` is a `BTreeMap`).
//! Typed decoders built on `JsonPull` are *fast paths for valid input
//! only*: on any surprise they return `None` and the caller re-runs the
//! DOM path, which produces the canonical error. Both properties are
//! pinned by `tests/prop_wire.rs` differential fuzzing.
//!
//! One deliberate semantic note: the DOM parser validates UTF-8 from
//! the first ordinary (non-escape) string character to the end of the
//! whole input. `JsonPull` performs that identical validation once, at
//! the first ordinary string character it ever sees, and then slices
//! strings zero-copy; error positions match because the DOM path also
//! fails at that first character.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::io::{self, Write};

use super::json::{Json, JsonError};

/// One parse event. `Key`/`Str` borrow from the input when the string
/// has no escapes (`Cow::Borrowed`) and only allocate when it does.
#[derive(Clone, Debug, PartialEq)]
pub enum Event<'a> {
    ObjStart,
    ObjEnd,
    ArrStart,
    ArrEnd,
    /// An object key (the following events form its value).
    Key(Cow<'a, str>),
    Str(Cow<'a, str>),
    Num(f64),
    Bool(bool),
    Null,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Frame {
    Obj,
    Arr,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum State {
    /// Before the top-level value.
    Start,
    /// Just consumed `{`: expect `}` or the first key.
    ObjFirst,
    /// Just consumed `,` inside an object: expect a key.
    ObjKey,
    /// Just consumed `:` (and trailing ws): expect a value.
    Value,
    /// Just consumed `[`: expect `]` or the first element.
    ArrFirst,
    /// Just consumed `,` inside an array: expect a value.
    ArrValue,
    /// A value inside a container just ended: expect `,` or the closer.
    AfterValue,
    /// The top-level value ended: expect end of input.
    Done,
}

/// Non-recursive streaming pull parser over a byte slice. Call
/// [`JsonPull::next`] until it returns `Ok(None)` (end of a fully
/// consumed document) or an error. Container depth lives in an explicit
/// stack, so arbitrarily nested input cannot overflow the call stack.
pub struct JsonPull<'a> {
    b: &'a [u8],
    i: usize,
    stack: Vec<Frame>,
    state: State,
    /// Position from which the remainder of the input has been
    /// validated as UTF-8 (`None` until the first ordinary string
    /// character forces the check). Enables zero-copy string slices.
    valid_from: Option<usize>,
}

impl<'a> JsonPull<'a> {
    pub fn new(b: &'a [u8]) -> JsonPull<'a> {
        JsonPull { b, i: 0, stack: Vec::new(), state: State::Start, valid_from: None }
    }

    /// Current byte position (error positions report this).
    pub fn pos(&self) -> usize {
        self.i
    }

    /// First byte of the upcoming value. Only meaningful directly after
    /// a [`Event::Key`] (whitespace after the `:` is already consumed);
    /// lets envelope decoders route `{`/`[` values to typed decoders.
    pub fn peek_value_byte(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    /// Pull the next event. `Ok(None)` means the document is complete
    /// and fully consumed (trailing whitespace allowed, anything else
    /// is the DOM parser's "trailing characters" error).
    pub fn next(&mut self) -> Result<Option<Event<'a>>, JsonError> {
        loop {
            match self.state {
                State::Start => {
                    self.skip_ws();
                    return self.value_event().map(Some);
                }
                State::Value => return self.value_event().map(Some),
                State::ObjFirst => {
                    self.skip_ws();
                    if self.peek() == Some(b'}') {
                        self.i += 1;
                        return Ok(Some(self.close(Frame::Obj)));
                    }
                    return self.key_event().map(Some);
                }
                State::ObjKey => {
                    self.skip_ws();
                    return self.key_event().map(Some);
                }
                State::ArrFirst => {
                    self.skip_ws();
                    if self.peek() == Some(b']') {
                        self.i += 1;
                        return Ok(Some(self.close(Frame::Arr)));
                    }
                    return self.value_event().map(Some);
                }
                State::ArrValue => {
                    self.skip_ws();
                    return self.value_event().map(Some);
                }
                State::AfterValue => {
                    self.skip_ws();
                    match self.stack.last() {
                        Some(Frame::Obj) => match self.peek() {
                            Some(b',') => {
                                self.i += 1;
                                self.state = State::ObjKey;
                            }
                            Some(b'}') => {
                                self.i += 1;
                                return Ok(Some(self.close(Frame::Obj)));
                            }
                            _ => return Err(self.err("expected ',' or '}'")),
                        },
                        Some(Frame::Arr) => match self.peek() {
                            Some(b',') => {
                                self.i += 1;
                                self.state = State::ArrValue;
                            }
                            Some(b']') => {
                                self.i += 1;
                                return Ok(Some(self.close(Frame::Arr)));
                            }
                            _ => return Err(self.err("expected ',' or ']'")),
                        },
                        None => unreachable!("AfterValue with an empty stack"),
                    }
                }
                State::Done => {
                    self.skip_ws();
                    if self.i != self.b.len() {
                        return Err(self.err("trailing characters"));
                    }
                    return Ok(None);
                }
            }
        }
    }

    /// Materialize the next value (and everything inside it) as a DOM
    /// `Json` — the cold-path escape hatch for fields a typed decoder
    /// does not understand. Non-recursive like the event loop.
    pub fn parse_value(&mut self) -> Result<Json, JsonError> {
        enum Holder {
            Arr(Vec<Json>),
            Obj(BTreeMap<String, Json>, Option<String>),
        }
        let mut stack: Vec<Holder> = Vec::new();
        loop {
            let ev = match self.next()? {
                Some(ev) => ev,
                None => return Err(self.err("unexpected character")),
            };
            let completed: Json = match ev {
                Event::ObjStart => {
                    stack.push(Holder::Obj(BTreeMap::new(), None));
                    continue;
                }
                Event::ArrStart => {
                    stack.push(Holder::Arr(Vec::new()));
                    continue;
                }
                Event::Key(k) => {
                    match stack.last_mut() {
                        Some(Holder::Obj(_, slot)) => *slot = Some(k.into_owned()),
                        _ => unreachable!("key outside an object"),
                    }
                    continue;
                }
                Event::ObjEnd => match stack.pop() {
                    Some(Holder::Obj(m, _)) => Json::Obj(m),
                    _ => unreachable!("unbalanced ObjEnd"),
                },
                Event::ArrEnd => match stack.pop() {
                    Some(Holder::Arr(v)) => Json::Arr(v),
                    _ => unreachable!("unbalanced ArrEnd"),
                },
                Event::Str(s) => Json::Str(s.into_owned()),
                Event::Num(x) => Json::Num(x),
                Event::Bool(b) => Json::Bool(b),
                Event::Null => Json::Null,
            };
            match stack.last_mut() {
                None => return Ok(completed),
                Some(Holder::Arr(v)) => v.push(completed),
                Some(Holder::Obj(m, slot)) => {
                    // last key wins, exactly like the DOM's BTreeMap insert
                    // lint:allow(panic-path): the state machine emits Key
                    // before Value inside an object, so the slot is Some
                    let k = slot.take().expect("value follows its key");
                    m.insert(k, completed);
                }
            }
        }
    }

    /// Consume and discard the next value (unknown fields on the hot
    /// path). Still validates it fully.
    pub fn skip_value(&mut self) -> Result<(), JsonError> {
        let mut depth = 0usize;
        loop {
            match self.next()? {
                None => return Err(self.err("unexpected character")),
                Some(Event::ObjStart | Event::ArrStart) => depth += 1,
                Some(Event::ObjEnd | Event::ArrEnd) => {
                    depth -= 1;
                    if depth == 0 {
                        return Ok(());
                    }
                }
                Some(Event::Key(_)) => {}
                Some(_) => {
                    if depth == 0 {
                        return Ok(());
                    }
                }
            }
        }
    }

    // ----- internals -------------------------------------------------------

    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str) -> Result<(), JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(())
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn close(&mut self, frame: Frame) -> Event<'a> {
        debug_assert_eq!(self.stack.last(), Some(&frame));
        self.stack.pop();
        self.state =
            if self.stack.is_empty() { State::Done } else { State::AfterValue };
        match frame {
            Frame::Obj => Event::ObjEnd,
            Frame::Arr => Event::ArrEnd,
        }
    }

    fn end_scalar(&mut self) {
        self.state =
            if self.stack.is_empty() { State::Done } else { State::AfterValue };
    }

    fn value_event(&mut self) -> Result<Event<'a>, JsonError> {
        match self.peek() {
            Some(b'{') => {
                self.i += 1;
                self.stack.push(Frame::Obj);
                self.state = State::ObjFirst;
                Ok(Event::ObjStart)
            }
            Some(b'[') => {
                self.i += 1;
                self.stack.push(Frame::Arr);
                self.state = State::ArrFirst;
                Ok(Event::ArrStart)
            }
            Some(b'"') => {
                let s = self.string()?;
                self.end_scalar();
                Ok(Event::Str(s))
            }
            Some(b't') => {
                self.lit("true")?;
                self.end_scalar();
                Ok(Event::Bool(true))
            }
            Some(b'f') => {
                self.lit("false")?;
                self.end_scalar();
                Ok(Event::Bool(false))
            }
            Some(b'n') => {
                self.lit("null")?;
                self.end_scalar();
                Ok(Event::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let x = self.number()?;
                self.end_scalar();
                Ok(Event::Num(x))
            }
            _ => Err(self.err("unexpected character")),
        }
    }

    fn key_event(&mut self) -> Result<Event<'a>, JsonError> {
        let k = self.string()?;
        self.skip_ws();
        self.expect_byte(b':')?;
        self.skip_ws();
        self.state = State::Value;
        Ok(Event::Key(k))
    }

    /// The DOM parser validates `&input[first_ordinary_char..]` (to the
    /// *end of the whole input*) at every ordinary string character; one
    /// check at the first such character is equivalent — every later
    /// ordinary character sits inside the already-validated suffix —
    /// and it is what licenses zero-copy slices and `utf8_len` steps.
    fn ensure_valid_utf8(&mut self) -> Result<(), JsonError> {
        if self.valid_from.is_none() {
            if std::str::from_utf8(&self.b[self.i..]).is_err() {
                return Err(self.err("invalid utf-8"));
            }
            self.valid_from = Some(self.i);
        }
        Ok(())
    }

    fn str_slice(&self, a: usize, b: usize) -> &'a str {
        // lint:allow(panic-path): ensure_valid_utf8 ran before any slice
        // is taken; re-validation here cannot fail
        std::str::from_utf8(&self.b[a..b]).expect("slice was validated as utf-8")
    }

    fn string(&mut self) -> Result<Cow<'a, str>, JsonError> {
        self.expect_byte(b'"')?;
        let start = self.i;
        // set on the first escape: everything before it was clean
        let mut owned: Option<String> = None;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let s = match owned {
                        Some(s) => Cow::Owned(s),
                        None => Cow::Borrowed(self.str_slice(start, self.i)),
                    };
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    let mut s = match owned.take() {
                        Some(s) => s,
                        None => self.str_slice(start, self.i).to_string(),
                    };
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                    owned = Some(s);
                }
                Some(c) => {
                    self.ensure_valid_utf8()?;
                    let n = utf8_len(c);
                    if let Some(s) = owned.as_mut() {
                        s.push_str(self.str_slice(self.i, self.i + n));
                    }
                    self.i += n;
                }
            }
        }
    }

    fn number(&mut self) -> Result<f64, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        // lint:allow(panic-path): the scanned range is ASCII digits/signs
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map_err(|_| self.err("invalid number"))
    }
}

/// Byte length of a UTF-8 scalar from its leading byte. Only called on
/// validated input, where a leading byte in `0x80..0xC0` cannot occur
/// at a character boundary.
fn utf8_len(lead: u8) -> usize {
    match lead {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Parse a complete document through the pull parser into a DOM value.
/// Same values, same error messages and byte positions as
/// `json::parse` (pinned by `tests/prop_wire.rs`).
pub fn parse_dom(input: &str) -> Result<Json, JsonError> {
    let mut p = JsonPull::new(input.as_bytes());
    let v = p.parse_value()?;
    match p.next()? {
        None => Ok(v),
        Some(_) => unreachable!("top-level value already completed"),
    }
}

// ----- direct-write serialization ------------------------------------------

#[derive(Clone, Copy)]
struct WFrame {
    obj: bool,
    first: bool,
}

/// Streaming JSON writer: emits straight into an `impl io::Write`
/// buffer with the exact number/escape formatting of `Json::to_string`.
/// Methods chain (`w.key("ok").bool(true)`); the first I/O error is
/// held until [`JsonWriter::finish`].
///
/// Because `Json::Obj` is a `BTreeMap`, the DOM always serializes
/// object keys sorted — byte-identical output therefore requires
/// callers to emit keys in sorted order, which debug builds assert.
pub struct JsonWriter<W: Write> {
    w: W,
    err: Option<io::Error>,
    stack: Vec<WFrame>,
    #[cfg(debug_assertions)]
    keys: Vec<Option<String>>,
}

impl<W: Write> JsonWriter<W> {
    pub fn new(w: W) -> JsonWriter<W> {
        JsonWriter {
            w,
            err: None,
            stack: Vec::new(),
            #[cfg(debug_assertions)]
            keys: Vec::new(),
        }
    }

    fn raw(&mut self, f: impl FnOnce(&mut W) -> io::Result<()>) {
        if self.err.is_none() {
            if let Err(e) = f(&mut self.w) {
                self.err = Some(e);
            }
        }
    }

    /// Comma management for a value in array (or top-level) position;
    /// object values get their separator from `key`.
    fn value_prelude(&mut self) {
        let need_comma = match self.stack.last_mut() {
            Some(f) if !f.obj => {
                let was_first = f.first;
                f.first = false;
                !was_first
            }
            _ => false,
        };
        if need_comma {
            self.raw(|w| w.write_all(b","));
        }
    }

    pub fn begin_obj(&mut self) -> &mut Self {
        self.value_prelude();
        self.raw(|w| w.write_all(b"{"));
        self.stack.push(WFrame { obj: true, first: true });
        #[cfg(debug_assertions)]
        self.keys.push(None);
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        let f = self.stack.pop();
        debug_assert!(matches!(f, Some(WFrame { obj: true, .. })), "end_obj outside object");
        #[cfg(debug_assertions)]
        self.keys.pop();
        self.raw(|w| w.write_all(b"}"));
        self
    }

    pub fn begin_arr(&mut self) -> &mut Self {
        self.value_prelude();
        self.raw(|w| w.write_all(b"["));
        self.stack.push(WFrame { obj: false, first: true });
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        let f = self.stack.pop();
        debug_assert!(matches!(f, Some(WFrame { obj: false, .. })), "end_arr outside array");
        self.raw(|w| w.write_all(b"]"));
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        let first = {
            // lint:allow(panic-path): writer-misuse guard — callers are
            // in-crate response builders, never request data
            let top = self.stack.last_mut().expect("key outside object");
            debug_assert!(top.obj, "key inside array");
            let was_first = top.first;
            top.first = false;
            was_first
        };
        #[cfg(debug_assertions)]
        {
            // lint:allow(panic-path): debug-only sorted-key tracker
            let slot = self.keys.last_mut().expect("key outside object");
            if let Some(prev) = slot {
                debug_assert!(
                    prev.as_str() < k,
                    "object keys must be emitted in sorted order \
                     (BTreeMap equivalence): {prev:?} then {k:?}"
                );
            }
            *slot = Some(k.to_string());
        }
        if !first {
            self.raw(|w| w.write_all(b","));
        }
        self.write_escaped(k);
        self.raw(|w| w.write_all(b":"));
        self
    }

    pub fn null(&mut self) -> &mut Self {
        self.value_prelude();
        self.raw(|w| w.write_all(b"null"));
        self
    }

    pub fn bool(&mut self, b: bool) -> &mut Self {
        self.value_prelude();
        self.raw(|w| w.write_all(if b { b"true" } else { b"false" }));
        self
    }

    /// `Json::to_string`'s exact number form: integral values below
    /// 1e15 in magnitude print as integers, everything else as `{x}`.
    pub fn num(&mut self, x: f64) -> &mut Self {
        self.value_prelude();
        // lint:allow(float-ord): fract() == 0.0 is the exact integrality test
        // for the canonical integer print form; no tolerance is wanted here.
        if x.fract() == 0.0 && x.abs() < 1e15 {
            let i = x as i64;
            self.raw(|w| write!(w, "{i}"));
        } else {
            self.raw(|w| write!(w, "{x}"));
        }
        self
    }

    pub fn str(&mut self, s: &str) -> &mut Self {
        self.value_prelude();
        self.write_escaped(s);
        self
    }

    /// `json::write_escaped`, byte for byte. Scans for the next byte
    /// needing an escape and bulk-writes the clean run before it (all
    /// escape-worthy characters are single ASCII bytes, so a byte scan
    /// is exact).
    fn write_escaped(&mut self, s: &str) {
        self.raw(|w| {
            w.write_all(b"\"")?;
            let bytes = s.as_bytes();
            let mut run = 0;
            for (i, &b) in bytes.iter().enumerate() {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    w.write_all(&bytes[run..i])?;
                    match b {
                        b'"' => w.write_all(b"\\\"")?,
                        b'\\' => w.write_all(b"\\\\")?,
                        b'\n' => w.write_all(b"\\n")?,
                        b'\r' => w.write_all(b"\\r")?,
                        b'\t' => w.write_all(b"\\t")?,
                        _ => write!(w, "\\u{:04x}", b)?,
                    }
                    run = i + 1;
                }
            }
            w.write_all(&bytes[run..])?;
            w.write_all(b"\"")
        });
    }

    /// Finish, returning the sink (or the first deferred I/O error).
    pub fn finish(self) -> io::Result<W> {
        debug_assert!(self.stack.is_empty(), "unclosed container at finish");
        match self.err {
            Some(e) => Err(e),
            None => Ok(self.w),
        }
    }
}

impl JsonWriter<Vec<u8>> {
    /// In-memory sink convenience: writing to a `Vec` cannot fail, and
    /// the writer only ever emits valid UTF-8.
    pub fn into_string(self) -> String {
        // lint:allow(panic-path): io::Write into a Vec is infallible and
        // the writer only emits valid UTF-8 (escaping is byte-exact)
        let buf = self.finish().expect("Vec sink never errors");
        // lint:allow(panic-path): same — the writer only emits UTF-8
        String::from_utf8(buf).expect("writer emits utf-8")
    }

    /// Close the outer object opened by [`obj_writer`] and return the
    /// response string.
    pub fn finish_obj(mut self) -> String {
        self.end_obj();
        self.into_string()
    }
}

/// Start a direct-write JSON object response in a reserved buffer.
pub fn obj_writer(capacity: usize) -> JsonWriter<Vec<u8>> {
    let mut w = JsonWriter::new(Vec::with_capacity(capacity));
    w.begin_obj();
    w
}

/// Serialize-self into a [`JsonWriter`] — the write-trait half of the
/// wire layer. Implementors must emit object keys in sorted order (see
/// [`JsonWriter`]).
pub trait JsonWrite {
    fn write_json<W: Write>(&self, w: &mut JsonWriter<W>);

    /// Render into a fresh reserved buffer.
    fn to_wire_string(&self) -> String {
        let mut w = JsonWriter::new(Vec::with_capacity(128));
        self.write_json(&mut w);
        w.into_string()
    }
}

impl JsonWrite for Json {
    fn write_json<W: Write>(&self, w: &mut JsonWriter<W>) {
        match self {
            Json::Null => {
                w.null();
            }
            Json::Bool(b) => {
                w.bool(*b);
            }
            Json::Num(x) => {
                w.num(*x);
            }
            Json::Str(s) => {
                w.str(s);
            }
            Json::Arr(v) => {
                w.begin_arr();
                for x in v {
                    x.write_json(w);
                }
                w.end_arr();
            }
            Json::Obj(m) => {
                w.begin_obj();
                for (k, v) in m {
                    w.key(k);
                    v.write_json(w);
                }
                w.end_obj();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn events(src: &str) -> Result<Vec<Event<'_>>, JsonError> {
        let mut p = JsonPull::new(src.as_bytes());
        let mut out = Vec::new();
        while let Some(ev) = p.next()? {
            out.push(ev);
        }
        Ok(out)
    }

    #[test]
    fn pull_yields_expected_events() {
        let evs = events(r#"{"a":[1,true,null],"b":"x"}"#).unwrap();
        assert_eq!(
            evs,
            vec![
                Event::ObjStart,
                Event::Key(Cow::Borrowed("a")),
                Event::ArrStart,
                Event::Num(1.0),
                Event::Bool(true),
                Event::Null,
                Event::ArrEnd,
                Event::Key(Cow::Borrowed("b")),
                Event::Str(Cow::Borrowed("x")),
                Event::ObjEnd,
            ]
        );
    }

    #[test]
    fn strings_are_zero_copy_until_escaped() {
        // no escapes (even non-ASCII): borrowed straight from the input
        let src = "[\"plain \u{e9}\",\"esc\\n\"]";
        let mut p = JsonPull::new(src.as_bytes());
        assert_eq!(p.next().unwrap(), Some(Event::ArrStart));
        match p.next().unwrap().unwrap() {
            Event::Str(Cow::Borrowed(s)) => assert_eq!(s, "plain \u{e9}"),
            other => panic!("expected borrowed: {other:?}"),
        }
        // an escape forces materialization
        match p.next().unwrap().unwrap() {
            Event::Str(Cow::Owned(s)) => assert_eq!(s, "esc\n"),
            other => panic!("expected owned: {other:?}"),
        }
    }

    #[test]
    fn parse_dom_matches_json_parse_on_valid_docs() {
        for src in [
            r#"{"a":[1,2.5,-3e2],"b":"x\"y","c":true,"d":null,"e":{}}"#,
            r#"[]"#,
            r#"{}"#,
            r#"  [ 1 , { "k" : [ true ] } ]  "#,
            r#""A\n\tπ""#,
            "3.25",
            "null",
            r#"{"dup":1,"dup":2}"#,
        ] {
            let dom = json::parse(src).unwrap();
            let pulled = parse_dom(src).unwrap();
            assert_eq!(dom, pulled, "{src}");
        }
    }

    #[test]
    fn errors_match_dom_positions() {
        for src in [
            "{", "[1,]", "12 34", "'single'", r#"{"a" 1}"#, "", "[1 2]",
            r#"{"a":1,}"#, "tru", r#""unterminated"#, r#""bad \q""#,
            r#""bad \u00"#, "-", "1e", "[",
        ] {
            let dom_err = json::parse(src).unwrap_err();
            let pull_err = parse_dom(src).unwrap_err();
            assert_eq!(dom_err.pos, pull_err.pos, "{src:?}");
            assert_eq!(dom_err.msg, pull_err.msg, "{src:?}");
        }
    }

    #[test]
    fn deep_nesting_does_not_recurse() {
        // far beyond what the recursive DOM parser could survive is not
        // testable differentially; match its tested depth and beyond
        let src = "[".repeat(200) + &"]".repeat(200);
        assert!(parse_dom(&src).is_ok());
    }

    #[test]
    fn skip_value_consumes_exactly_one_value() {
        let mut p = JsonPull::new(br#"{"skip":{"deep":[1,{"x":2}]},"keep":7}"#.as_slice());
        assert_eq!(p.next().unwrap(), Some(Event::ObjStart));
        assert!(matches!(p.next().unwrap(), Some(Event::Key(k)) if k == "skip"));
        p.skip_value().unwrap();
        assert!(matches!(p.next().unwrap(), Some(Event::Key(k)) if k == "keep"));
        assert_eq!(p.next().unwrap(), Some(Event::Num(7.0)));
        assert_eq!(p.next().unwrap(), Some(Event::ObjEnd));
        assert_eq!(p.next().unwrap(), None);
    }

    #[test]
    fn writer_matches_dom_serialization() {
        for src in [
            r#"{"a":[1,2.5,-3e2],"b":"x\"y","c":true,"d":null,"e":{}}"#,
            r#"{"s":"ab\nπ","big":1e300,"neg":-0.5}"#,
            "[[],{},[null]]",
        ] {
            let v = json::parse(src).unwrap();
            assert_eq!(v.to_wire_string(), v.to_string(), "{src}");
        }
        assert_eq!(Json::Num(3.0).to_wire_string(), "3");
        assert_eq!(Json::Num(3.25).to_wire_string(), "3.25");
        assert_eq!(Json::Num(-0.0).to_wire_string(), "0");
    }

    #[test]
    fn writer_chains_and_manages_commas() {
        let mut w = obj_writer(64);
        w.key("a").num(1.0);
        w.key("b").begin_arr().num(1.0).str("two").begin_obj().end_obj().end_arr();
        w.key("c").bool(false);
        assert_eq!(w.finish_obj(), r#"{"a":1,"b":[1,"two",{}],"c":false}"#);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "sorted order")]
    fn writer_asserts_sorted_keys() {
        let mut w = obj_writer(16);
        w.key("b").num(1.0);
        w.key("a").num(2.0);
        let _ = w.finish_obj();
    }

    #[test]
    fn invalid_utf8_bytes_never_parse() {
        // a pull parse over invalid UTF-8 must fail (the DOM path is
        // only ever handed &str); the whole-suffix check fires at the
        // first ordinary string character — here the key's 'k'
        let mut bad = b"{\"k\":\"a".to_vec();
        bad.push(0xff);
        bad.extend_from_slice(b"\"}");
        let mut p = JsonPull::new(&bad);
        let mut err = None;
        loop {
            match p.next() {
                Ok(Some(_)) => continue,
                Ok(None) => break,
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        let err = err.expect("must fail");
        assert_eq!(err.msg, "invalid utf-8");
        assert_eq!(err.pos, 2, "fails at the first ordinary string char");
        // outside strings: plain syntax error
        assert!(JsonPull::new(&[0xff, 0xfe]).next().is_err());
    }
}
