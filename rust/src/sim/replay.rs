//! Event-driven workload replay — an *independent* validation path for
//! solutions (deliberately not sharing code with Solution::verify): tasks
//! arrive/depart as timed events, per-node loads are updated incrementally,
//! and capacity is checked at every event point. Also produces the
//! utilization statistics the examples report.

use crate::model::{Instance, Solution};

/// Per-slot cluster utilization sample.
#[derive(Clone, Debug)]
pub struct UtilizationSample {
    pub timeslot: u32,
    /// Mean over nodes of (load / capacity) averaged over dimensions.
    pub mean_node_utilization: f64,
    /// Max over nodes and dimensions of load / capacity.
    pub peak_node_utilization: f64,
    pub active_tasks: usize,
}

#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub samples: Vec<UtilizationSample>,
    pub overloads: usize,
    /// Time-averaged mean node utilization.
    pub avg_utilization: f64,
    /// Peak concurrent active tasks.
    pub peak_tasks: usize,
}

/// Replay the workload against a placement.
pub fn replay(inst: &Instance, sol: &Solution) -> ReplayReport {
    let dims = inst.dims();
    let t_len = inst.horizon as usize;
    let n_nodes = sol.nodes.len();

    // event lists: (slot, node, (task, segment), is_start) — one
    // arrival/departure pair per demand *segment*, so shaped tasks load
    // and unload their exact per-window demand (flat tasks emit the same
    // two events they always did)
    #[derive(Clone, Copy)]
    struct Ev {
        slot: u32,
        node: usize,
        task: usize,
        seg: usize,
        start: bool,
    }
    let mut events: Vec<Ev> = Vec::with_capacity(inst.n_tasks() * 2);
    for (u, assigned) in sol.assignment.iter().enumerate() {
        let Some(node) = assigned else { continue };
        for (si, seg) in inst.tasks[u].segments().iter().enumerate() {
            events.push(Ev { slot: seg.start, node: *node, task: u, seg: si, start: true });
            // departure processed after the last active slot
            events.push(Ev { slot: seg.end + 1, node: *node, task: u, seg: si, start: false });
        }
    }
    // departures before arrivals at the same slot
    events.sort_by_key(|e| (e.slot, e.start));

    let mut load = vec![0.0f64; n_nodes * dims];
    let mut active = 0usize;
    let mut overloads = 0usize;
    let mut samples = Vec::with_capacity(t_len);
    let mut ei = 0usize;
    let mut peak_tasks = 0usize;

    for slot in 0..t_len as u32 {
        while ei < events.len() && events[ei].slot == slot {
            let ev = events[ei];
            let dem = &inst.tasks[ev.task].segments()[ev.seg].demand;
            let sign = if ev.start { 1.0 } else { -1.0 };
            for d in 0..dims {
                load[ev.node * dims + d] += sign * dem[d];
            }
            // contiguous segments depart/arrive at the same slot
            // (departures first), so the running count stays the number
            // of active *tasks*
            if ev.start {
                active += 1;
            } else {
                active -= 1;
            }
            ei += 1;
        }
        peak_tasks = peak_tasks.max(active);

        let mut busy_nodes = 0usize;
        let mut util_sum = 0.0;
        let mut peak: f64 = 0.0;
        for (ni, node) in sol.nodes.iter().enumerate() {
            let cap = &inst.node_types[node.type_idx].capacity;
            let mut node_util = 0.0;
            let mut node_busy = false;
            for d in 0..dims {
                let frac = load[ni * dims + d] / cap[d];
                node_util += frac / dims as f64;
                peak = peak.max(frac);
                if frac > 1.0 + 1e-9 {
                    overloads += 1;
                }
                if frac > 1e-12 {
                    node_busy = true;
                }
            }
            if node_busy {
                busy_nodes += 1;
                util_sum += node_util;
            }
        }
        samples.push(UtilizationSample {
            timeslot: slot,
            mean_node_utilization: if busy_nodes > 0 { util_sum / busy_nodes as f64 } else { 0.0 },
            peak_node_utilization: peak,
            active_tasks: active,
        });
    }
    let avg = if samples.is_empty() {
        0.0
    } else {
        samples.iter().map(|s| s.mean_node_utilization).sum::<f64>() / samples.len() as f64
    };
    ReplayReport { samples, overloads, avg_utilization: avg, peak_tasks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::pipeline::{Penalty, Pipeline};
    use crate::algo::placement::FitPolicy;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::solver::NativePdhgSolver;
    use crate::model::{trim, NodeType, PlacedNode, Task};

    #[test]
    fn valid_solution_replays_clean() {
        let inst = generate(&SynthParams { n: 80, m: 4, ..Default::default() }, 9);
        let tr = trim(&inst).instance;
        let rep = Pipeline::new()
            .map(Penalty::both())
            .fit(FitPolicy::FirstFit)
            .run(&tr, &NativePdhgSolver::default())
            .unwrap();
        let rr = replay(&tr, &rep.solution);
        assert_eq!(rr.overloads, 0);
        assert!(rr.avg_utilization > 0.0 && rr.avg_utilization <= 1.0 + 1e-9);
        assert!(rr.peak_tasks <= 80);
        assert_eq!(rr.samples.len(), tr.horizon as usize);
    }

    #[test]
    fn overload_caught() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.7], 0, 1), Task::new(1, vec![0.7], 1, 2)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            3,
        );
        let mut sol = Solution::new(2);
        sol.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0, 1] });
        sol.assignment = vec![Some(0), Some(0)];
        let rep = replay(&inst, &sol);
        assert!(rep.overloads > 0);
        // replay agrees with the verifier
        assert!(sol.verify(&inst).is_err());
    }

    #[test]
    fn shaped_tasks_replay_per_segment() {
        use crate::model::DemandSeg;
        // complementary shapes share a node at exactly full utilization;
        // the replay tracks the segment demands, not the peaks
        let mk = |id, hi_first: bool| {
            let (a, b) = if hi_first { (0.8, 0.2) } else { (0.2, 0.8) };
            Task::piecewise(
                id,
                vec![
                    DemandSeg { start: 0, end: 1, demand: vec![a] },
                    DemandSeg { start: 2, end: 3, demand: vec![b] },
                ],
            )
        };
        let inst = Instance::new(
            vec![mk(0, true), mk(1, false)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            4,
        );
        let mut sol = Solution::new(2);
        sol.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0, 1] });
        sol.assignment = vec![Some(0), Some(0)];
        let rep = replay(&inst, &sol);
        assert_eq!(rep.overloads, 0, "{rep:?}");
        for s in &rep.samples {
            assert!((s.peak_node_utilization - 1.0).abs() < 1e-12, "{s:?}");
            assert_eq!(s.active_tasks, 2);
        }
        assert_eq!(rep.peak_tasks, 2);
        // and an actual per-slot overlap of high windows is caught
        let inst2 = Instance::new(
            vec![mk(0, true), mk(1, true)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            4,
        );
        let rep2 = replay(&inst2, &sol);
        assert!(rep2.overloads > 0);
        assert!(sol.verify(&inst2).is_err());
    }

    #[test]
    fn utilization_accounting() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.5], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            2,
        );
        let mut sol = Solution::new(1);
        sol.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0] });
        sol.assignment = vec![Some(0)];
        let rep = replay(&inst, &sol);
        assert!((rep.samples[0].peak_node_utilization - 0.5).abs() < 1e-12);
        assert_eq!(rep.samples[1].active_tasks, 0);
        assert!((rep.samples[1].peak_node_utilization).abs() < 1e-12);
    }
}
