//! Admission/auto-scaling simulator — the paper's closing future-work item
//! ("enhancing the scheduler and auto-scaling algorithms to better
//! leverage the output from TL-Rightsizing").
//!
//! Given a rightsized cluster and an *online* task stream (the planned
//! workload plus optional unplanned surprise load), the simulator admits
//! each arrival first-fit into the fixed cluster; what does not fit is
//! either rejected (fixed edge cluster) or served by renting overflow
//! nodes on demand (public-cloud hybrid). Reports admission rate and
//! overflow spend — quantifying how much headroom a plan really has.
//!
//! Admission runs on the plan-session repair engine
//! ([`crate::algo::repair::Pool`]) — the exact code path the planning
//! service's session `delta` verb admits through — so what the sim
//! predicts is what the deployed admission path does.

use anyhow::{ensure, Result};

use crate::algo::placement::FitPolicy;
use crate::algo::repair::Pool;
use crate::io::workload::WorkloadSource;
use crate::model::{Instance, Solution, Task};

#[derive(Clone, Debug)]
pub struct AutoscaleReport {
    pub admitted: usize,
    pub rejected: usize,
    pub overflow_nodes: usize,
    /// Cost of rented overflow capacity (0 when renting is disabled).
    pub overflow_cost: f64,
    /// Planned cluster cost, for comparison.
    pub planned_cost: f64,
}

impl AutoscaleReport {
    pub fn admission_rate(&self) -> f64 {
        let total = self.admitted + self.rejected;
        if total == 0 {
            1.0
        } else {
            self.admitted as f64 / total as f64
        }
    }
}

/// Stress-test report: the planned workload replayed on its own cluster,
/// then the planned + surprise load in fixed and hybrid modes.
#[derive(Clone, Debug)]
pub struct StressReport {
    /// The surprise workload's label.
    pub surprise: String,
    pub surprise_tasks: usize,
    /// Planned load on the planned cluster (admission should be 100%).
    pub planned: AutoscaleReport,
    /// Planned + surprise load, rejections allowed (fixed edge cluster).
    pub fixed: AutoscaleReport,
    /// Planned + surprise load with rented overflow (hybrid cloud).
    pub hybrid: AutoscaleReport,
}

/// Stress the plan for `inst` with surprise load drawn from any
/// registered workload source — the sim-side consumer of the unified
/// workload subsystem. The surprise source must produce instances with
/// the same dimensionality; its tasks are re-id'd after the planned ones.
pub fn stress(
    inst: &Instance,
    plan: &Solution,
    surprise: &dyn WorkloadSource,
    seed: u64,
    policy: FitPolicy,
) -> Result<StressReport> {
    let extra = surprise.generate(seed)?;
    ensure!(
        extra.dims() == inst.dims(),
        "surprise workload '{}' has D={}, plan has D={}",
        surprise.label(),
        extra.dims(),
        inst.dims()
    );
    // both loads must live on one timeline; silently clipping late
    // arrivals would pile them onto the final slot as an artificial
    // mega-spike, so a longer surprise horizon is an error instead
    ensure!(
        extra.horizon <= inst.horizon,
        "surprise workload '{}' spans {} slots but the plan's timeline has {} — \
         set horizon={} on the surprise spec",
        surprise.label(),
        extra.horizon,
        inst.horizon,
        inst.horizon
    );
    let planned = simulate_with_hints(
        inst,
        plan,
        &inst.tasks,
        policy,
        false,
        Some(&plan.assignment),
    );
    let mut stream = inst.tasks.clone();
    let base = stream.len() as u64;
    stream.extend(
        extra
            .tasks
            .iter()
            .map(|t| t.with_id(base + t.id)),
    );
    let fixed = simulate(inst, plan, &stream, policy, false);
    let hybrid = simulate(inst, plan, &stream, policy, true);
    Ok(StressReport {
        surprise: surprise.label(),
        surprise_tasks: extra.tasks.len(),
        planned,
        fixed,
        hybrid,
    })
}

/// Simulate serving `stream` on the cluster purchased by `plan`.
///
/// `allow_overflow`: rent a penalty-best node for any arrival that does
/// not fit (hybrid mode); otherwise reject it (fixed edge cluster).
pub fn simulate(
    inst: &Instance,
    plan: &Solution,
    stream: &[Task],
    policy: FitPolicy,
    allow_overflow: bool,
) -> AutoscaleReport {
    simulate_with_hints(inst, plan, stream, policy, allow_overflow, None)
}

/// Like [`simulate`], with optional placement hints: `hints[u]` is the
/// planned node index for stream task `u` (tried first — a scheduler
/// executing its own plan admits the planned load by construction).
pub fn simulate_with_hints(
    inst: &Instance,
    plan: &Solution,
    stream: &[Task],
    policy: FitPolicy,
    allow_overflow: bool,
    hints: Option<&[Option<usize>]>,
) -> AutoscaleReport {
    // Build the purchased-but-empty cluster; stream tasks are placed into
    // it online. Stream tasks must share the instance's dimensionality.
    let dims = inst.dims();
    for t in stream {
        assert_eq!(t.dims(), dims, "stream task {} dims", t.id);
    }
    // A synthetic instance holding the stream tasks (placement engine
    // operates on instance task indices).
    let horizon = inst
        .horizon
        .max(stream.iter().map(|t| t.end + 1).max().unwrap_or(1));
    let sim_inst = Instance::new(stream.to_vec(), inst.node_types.clone(), horizon);

    // the purchased-but-empty planned pool, plus a rented overflow pool
    // — both driven through the session repair engine's admit path
    let mut pool = Pool::empty_from_plan(&sim_inst, plan);
    let mut overflow = Pool::new();

    let mut order: Vec<usize> = (0..stream.len()).collect();
    order.sort_by_key(|&u| (stream[u].start, u));

    let mut admitted = 0usize;
    let mut rejected = 0usize;
    let mut overflow_cost = 0.0;

    for u in order {
        let hint = hints.and_then(|hs| hs.get(u).copied().flatten());
        if pool.try_admit(&sim_inst, u, policy, hint).is_some()
            || overflow.try_admit(&sim_inst, u, policy, None).is_some()
        {
            admitted += 1;
            continue;
        }
        if allow_overflow {
            // rent the cheapest admitting type
            let b = (0..sim_inst.n_types())
                .filter(|&b| sim_inst.node_types[b].admits(stream[u].peak()))
                .min_by(|&a, &b| {
                    sim_inst.node_types[a]
                        .cost
                        .total_cmp(&sim_inst.node_types[b].cost)
                        .then(a.cmp(&b))
                });
            match b {
                Some(b) => {
                    overflow
                        .buy_and_place(&sim_inst, u, b)
                        .expect("admits() pre-checked the empty node");
                    overflow_cost += sim_inst.node_types[b].cost;
                    admitted += 1;
                }
                None => rejected += 1,
            }
        } else {
            rejected += 1;
        }
    }

    AutoscaleReport {
        admitted,
        rejected,
        overflow_nodes: overflow.len(),
        overflow_cost,
        planned_cost: plan.cost(inst),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::algorithms::lp_map_best;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::solver::NativePdhgSolver;
    use crate::model::trim;

    #[test]
    fn planned_workload_fully_admitted() {
        // replaying exactly the planned tasks on the planned cluster must
        // admit everything without overflow
        let inst = generate(&SynthParams { n: 80, m: 4, ..Default::default() }, 2);
        let tr = trim(&inst).instance;
        let rep = lp_map_best(&tr, &NativePdhgSolver::default(), true).unwrap();
        let out = simulate_with_hints(
            &tr, &rep.solution, &tr.tasks, FitPolicy::FirstFit, false,
            Some(&rep.solution.assignment));
        assert_eq!(out.rejected, 0, "{out:?}");
        assert_eq!(out.admission_rate(), 1.0);
        assert_eq!(out.overflow_nodes, 0);
    }

    #[test]
    fn surprise_load_needs_overflow() {
        let inst = generate(&SynthParams { n: 60, m: 4, ..Default::default() }, 3);
        let tr = trim(&inst).instance;
        let rep = lp_map_best(&tr, &NativePdhgSolver::default(), true).unwrap();
        // double the workload: the second copy is unplanned surprise load
        let mut stream = tr.tasks.clone();
        let base = stream.len() as u64;
        stream.extend(tr.tasks.iter().map(|t| {
            t.with_id(base + t.id)
        }));
        let fixed = simulate(&tr, &rep.solution, &stream, FitPolicy::FirstFit, false);
        let hybrid = simulate(&tr, &rep.solution, &stream, FitPolicy::FirstFit, true);
        assert!(fixed.admission_rate() < 1.0, "{fixed:?}");
        assert_eq!(hybrid.rejected, 0, "{hybrid:?}");
        assert!(hybrid.overflow_cost > 0.0);
        // renting overflow for a doubled load should cost less than the
        // whole planned cluster again times some slack
        assert!(hybrid.overflow_cost < 3.0 * hybrid.planned_cost, "{hybrid:?}");
    }

    #[test]
    fn stress_with_workload_source() {
        use crate::io::workload::parse_workload;
        let source = parse_workload("synth:n=60,m=4,dims=5,horizon=24").unwrap();
        let inst = source.generate(2).unwrap();
        let tr = trim(&inst).instance;
        let rep = lp_map_best(&tr, &NativePdhgSolver::default(), true).unwrap();
        // spiky surprise load on the planned cluster, through the
        // registry, generated on the plan's (trimmed) timeline
        let surprise = parse_workload(&format!(
            "spiky:services=40,dims=5,horizon={},dem=0.01..0.1",
            tr.horizon
        ))
        .unwrap();
        let out = stress(&tr, &rep.solution, surprise.as_ref(), 9, FitPolicy::FirstFit)
            .unwrap();
        assert_eq!(out.planned.rejected, 0, "{out:?}");
        assert_eq!(out.surprise_tasks, 40);
        assert!(out.surprise.starts_with("spiky"));
        // hybrid mode admits everything the fixed cluster cannot
        assert_eq!(out.hybrid.rejected, 0, "{out:?}");
        assert!(out.fixed.admitted + out.fixed.rejected == 60 + 40);
        // dimension mismatches error instead of panicking
        let bad = parse_workload("spiky:services=5,dims=2").unwrap();
        assert!(stress(&tr, &rep.solution, bad.as_ref(), 1, FitPolicy::FirstFit).is_err());
        // a surprise timeline longer than the plan's is an error, not a
        // silent clip onto the final slot
        let long = parse_workload(&format!(
            "spiky:services=5,dims=5,horizon={}",
            tr.horizon + 10
        ))
        .unwrap();
        let err = stress(&tr, &rep.solution, long.as_ref(), 1, FitPolicy::FirstFit)
            .unwrap_err()
            .to_string();
        assert!(err.contains("set horizon="), "{err}");
    }

    #[test]
    fn empty_stream() {
        let inst = generate(&SynthParams { n: 20, m: 3, ..Default::default() }, 4);
        let tr = trim(&inst).instance;
        let rep = lp_map_best(&tr, &NativePdhgSolver::default(), false).unwrap();
        let out = simulate(&tr, &rep.solution, &[], FitPolicy::FirstFit, false);
        assert_eq!(out.admitted + out.rejected, 0);
        assert_eq!(out.admission_rate(), 1.0);
    }
}
