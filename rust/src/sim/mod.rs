//! Workload replay simulator — independent solution validation.

pub mod autoscale;
pub mod replay;
