//! Service runtime benchmarks: solve throughput and latency through a
//! real TCP socket at 1 / 4 / 16 concurrent clients.
//!
//! Each level runs a fresh runtime (`workers = clients`, queue 2x) and
//! drives it with lock-step RPC clients (send one solve, read the
//! answer, repeat), so per-request latencies are honest and throughput
//! reflects worker-pool concurrency rather than client-side pipelining.
//! Writes `BENCH_service.json` with `concurrent_vs_sequential_speedup`
//! (level-16 rps over level-1 rps) so the accept/worker split's win is
//! tracked PR over PR. `TLRS_BENCH_QUICK=1` shrinks levels and request
//! counts for the tier-1 smoke.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::coordinator::runtime::{RuntimeConfig, ServiceRuntime};
use tlrs::io::files;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::util::bench::{fmt_ns, BenchResult};
use tlrs::util::json::Json;
use tlrs::util::stats;

struct LevelOutcome {
    clients: usize,
    requests: usize,
    rps: f64,
    result: BenchResult,
    p50_ms: f64,
    p95_ms: f64,
}

/// One concurrency level: spin up a runtime sized for `clients`, hammer
/// it with lock-step RPC clients, tear it down.
fn run_level(clients: usize, per_client: usize, req_line: &str) -> LevelOutcome {
    let planner = Arc::new(Planner::new(Backend::Native).unwrap());
    let cfg = RuntimeConfig {
        workers: clients,
        queue: 2 * clients,
        ..RuntimeConfig::default()
    };
    let handle = ServiceRuntime::bind(planner, "127.0.0.1:0", cfg).unwrap().spawn();
    let addr = handle.addr;

    let t0 = Instant::now();
    let latencies_ns: Vec<f64> = std::thread::scope(|s| {
        let workers: Vec<_> = (0..clients)
            .map(|_| {
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut lats = Vec::with_capacity(per_client);
                    let mut line = String::new();
                    for _ in 0..per_client {
                        let t = Instant::now();
                        stream.write_all(req_line.as_bytes()).unwrap();
                        stream.write_all(b"\n").unwrap();
                        stream.flush().unwrap();
                        line.clear();
                        reader.read_line(&mut line).unwrap();
                        lats.push(t.elapsed().as_nanos() as f64);
                        assert!(line.contains("\"ok\":true"), "bad response: {line}");
                    }
                    lats
                })
            })
            .collect();
        workers.into_iter().flat_map(|w| w.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    handle.shutdown_and_join().unwrap();

    let requests = clients * per_client;
    let rps = requests as f64 / wall.max(1e-9);
    let result = BenchResult {
        name: format!("service/solve-latency c={clients}"),
        mean_ns: stats::mean(&latencies_ns),
        std_ns: stats::stddev(&latencies_ns),
        min_ns: stats::min(&latencies_ns),
        samples: latencies_ns.len(),
        iters_per_sample: 1,
    };
    println!("{}", result.report_line());
    let p50_ms = stats::percentile(&latencies_ns, 50.0) / 1e6;
    let p95_ms = stats::percentile(&latencies_ns, 95.0) / 1e6;
    println!(
        "service/solve-throughput c={clients:<3} {rps:>8.1} req/s  \
         (p50 {p50_ms:.2} ms, p95 {p95_ms:.2} ms, {requests} reqs in {wall:.2}s)"
    );
    LevelOutcome { clients, requests, rps, result, p50_ms, p95_ms }
}

fn main() {
    println!("== service benches ==");
    let quick = std::env::var("TLRS_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let levels: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    let per_client = if quick { 4 } else { 10 };

    // one shared request line: a small fast solve so the measurement is
    // dominated by runtime dispatch + solver work, not instance size
    let inst = generate(&SynthParams { n: 20, m: 3, ..Default::default() }, 7);
    let req_line = Json::obj(vec![
        ("instance", files::instance_to_json(&inst)),
        ("algorithm", Json::Str("penalty-map-f".into())),
    ])
    .to_string();

    let outcomes: Vec<LevelOutcome> =
        levels.iter().map(|&c| run_level(c, per_client, &req_line)).collect();

    let base = &outcomes[0];
    let top = outcomes.last().unwrap();
    let speedup = top.rps / base.rps.max(1e-9);
    println!(
        "concurrent vs sequential speedup: {speedup:.2}x \
         ({} client(s) {:.1} req/s -> {} clients {:.1} req/s, mean latency {} -> {})",
        base.clients,
        base.rps,
        top.clients,
        top.rps,
        fmt_ns(base.result.mean_ns),
        fmt_ns(top.result.mean_ns)
    );

    let rows = Json::Arr(
        outcomes
            .iter()
            .map(|o| {
                Json::obj(vec![
                    ("clients", Json::Num(o.clients as f64)),
                    ("requests", Json::Num(o.requests as f64)),
                    ("rps", Json::Num(o.rps)),
                    ("p50_ms", Json::Num(o.p50_ms)),
                    ("p95_ms", Json::Num(o.p95_ms)),
                ])
            })
            .collect(),
    );
    let json = Json::obj(vec![
        ("bench", Json::Str("service".into())),
        ("quick", Json::Bool(quick)),
        ("levels", rows),
        ("concurrent_vs_sequential_speedup", Json::Num(speedup)),
        (
            "results",
            Json::Arr(outcomes.iter().map(|o| o.result.to_json()).collect()),
        ),
    ]);
    let path = "BENCH_service.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_service.json");
    println!("wrote {path}");
}
