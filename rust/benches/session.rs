//! Session benchmarks: incremental delta re-solve vs from-scratch
//! re-solve on a GCT-like trace (week-long timeline, real machine
//! shapes).
//!
//! A ≥100-delta admit/retire/reshape stream is replayed through a
//! `PlanSession` (pure incremental mode — the speedup being measured is
//! repair + LB refresh + per-slot verify against what a sessionless
//! deployment must do per delta: rebuild and re-solve the whole
//! instance). Writes `BENCH_session.json` with
//! `incremental_vs_scratch_speedup` so the win is tracked PR over PR.
//! `TLRS_BENCH_QUICK=1` shrinks the workload for the tier-1 smoke.

use tlrs::algo::pipeline::parse_portfolio;
use tlrs::coordinator::session::{PlanSession, SessionConfig};
use tlrs::io::gct_like;
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::{trim, Delta, Instance, Task};
use tlrs::util::bench::{fmt_ns, BenchResult};
use tlrs::util::rng::Rng;
use tlrs::util::stats;

/// Deterministic admit/retire/reshape stream over the live id set.
fn delta_stream(inst: &Instance, spare: &[Task], seed: u64, len: usize) -> Vec<Delta> {
    let mut rng = Rng::new(seed);
    let mut live: Vec<u64> = inst.tasks.iter().map(|t| t.id).collect();
    let mut spare_iter = spare.iter();
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        let roll = rng.below(10);
        if (roll < 5 || live.len() < 20) && spare_iter.len() > 0 {
            let t = spare_iter.next().unwrap().clone();
            live.push(t.id);
            out.push(Delta::Admit { tasks: vec![t] });
        } else if roll < 8 {
            let i = rng.below(live.len() as u64) as usize;
            let id = live.swap_remove(i);
            out.push(Delta::Retire { ids: vec![id] });
        } else {
            let i = rng.below(live.len() as u64) as usize;
            let id = live[i];
            // shrink-or-grow reshape within the trace's demand bounds
            let f = rng.uniform(0.5, 1.5);
            let u = inst.tasks.iter().chain(spare).find(|t| t.id == id);
            let span = u.map(|t| (t.start, t.end)).unwrap_or((0, 0));
            let demand: Vec<f64> = u
                .map(|t| t.peak().iter().map(|d| (d * f).clamp(2e-3, 0.25)).collect())
                .unwrap_or_else(|| vec![0.05, 0.05]);
            out.push(Delta::Reshape { task: Task::new(id, demand, span.0, span.1) });
        }
    }
    out
}

fn main() {
    println!("== session benches ==");
    let quick = std::env::var("TLRS_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let n = if quick { 160 } else { 260 };
    let n_deltas = 120; // the acceptance floor is a >= 100-delta stream
    let scratch_samples = if quick { 3 } else { 8 };
    let algo = "lp-map-f";

    // GCT-like scenario on the full week timeline, plus spare trace
    // tasks for admits (re-id'd above the live range)
    let trace = gct_like::generate_trace(2 * n + 400, 7);
    let mut inst = trace.sample_scenario(n, 8, 1);
    tlrs::model::CostModel::homogeneous(inst.dims()).apply(&mut inst.node_types);
    let spare: Vec<Task> = trace
        .sample_scenario(2 * n, 8, 2)
        .tasks
        .iter()
        .enumerate()
        .map(|(i, t)| t.with_id((n + i) as u64))
        .collect();
    let deltas = delta_stream(&inst, &spare, 99, n_deltas);

    // --- incremental: one session, the whole delta stream ---------------
    let cfg = SessionConfig { algo: algo.into(), escalate_ratio: None, ..Default::default() };
    let t_open = std::time::Instant::now();
    let (mut session, open) = PlanSession::open(inst.clone(), cfg).unwrap();
    println!(
        "session open: {} tasks, cost {:.4}, LB {:.4} in {}",
        open.n_tasks,
        open.cost,
        open.lower_bound,
        fmt_ns(t_open.elapsed().as_nanos() as f64)
    );
    let mut per_delta_ns: Vec<f64> = Vec::with_capacity(n_deltas);
    let mut checkpoints: Vec<(usize, Instance)> = Vec::new();
    for (i, d) in deltas.iter().enumerate() {
        let t0 = std::time::Instant::now();
        let rep = session.apply(d).unwrap(); // apply() verifies per slot
        per_delta_ns.push(t0.elapsed().as_nanos() as f64);
        assert!(
            rep.cost >= rep.lower_bound - 1e-6,
            "delta {i}: cost {} below certified LB {}",
            rep.cost,
            rep.lower_bound
        );
        if (i + 1) % (n_deltas / scratch_samples).max(1) == 0 {
            checkpoints.push((i, session.instance().clone()));
        }
    }
    let incr_mean = stats::mean(&per_delta_ns);
    let incremental = BenchResult {
        name: format!("session/incremental-delta gct n~{n} T=2016"),
        mean_ns: incr_mean,
        std_ns: stats::stddev(&per_delta_ns),
        min_ns: stats::min(&per_delta_ns),
        samples: per_delta_ns.len(),
        iters_per_sample: 1,
    };
    println!("{}", incremental.report_line());
    let final_cost = session.cost();
    let final_lb = session.lower_bound();
    println!(
        "final: cost {final_cost:.4}, LB {final_lb:.4} (x{:.3}), {} nodes, {} tasks",
        final_cost / final_lb.max(1e-12),
        session.n_nodes(),
        session.n_tasks()
    );

    // --- from-scratch: full one-shot re-solve at sampled checkpoints ----
    // (what a sessionless deployment pays per delta: trim + portfolio)
    let solver = NativePdhgSolver::default();
    let mut scratch_ns: Vec<f64> = Vec::with_capacity(checkpoints.len());
    for (i, snapshot) in &checkpoints {
        let t0 = std::time::Instant::now();
        let tr = trim(snapshot).instance;
        let race = parse_portfolio(algo).unwrap().run(&tr, &solver).unwrap();
        let rep = race.best();
        rep.solution.verify(&tr).unwrap();
        scratch_ns.push(t0.elapsed().as_nanos() as f64);
        let _ = i;
    }
    let scratch_mean = stats::mean(&scratch_ns);
    let scratch = BenchResult {
        name: format!("session/from-scratch-resolve gct n~{n}"),
        mean_ns: scratch_mean,
        std_ns: stats::stddev(&scratch_ns),
        min_ns: stats::min(&scratch_ns),
        samples: scratch_ns.len(),
        iters_per_sample: 1,
    };
    println!("{}", scratch.report_line());

    let speedup = scratch_mean / incr_mean.max(1.0);
    println!(
        "incremental vs from-scratch speedup: {speedup:.1}x \
         (scratch {} -> incremental {})",
        fmt_ns(scratch_mean),
        fmt_ns(incr_mean)
    );
    if speedup < 5.0 {
        eprintln!("WARNING: incremental speedup {speedup:.1}x below the 5x target");
    }

    let (nd, repairs, resolves) = session.delta_counts();
    let json = tlrs::util::json::Json::obj(vec![
        ("bench", tlrs::util::json::Json::Str("session".into())),
        ("quick", tlrs::util::json::Json::Bool(quick)),
        ("n", tlrs::util::json::Json::Num(n as f64)),
        ("n_deltas", tlrs::util::json::Json::Num(nd as f64)),
        ("repairs", tlrs::util::json::Json::Num(repairs as f64)),
        ("resolves", tlrs::util::json::Json::Num(resolves as f64)),
        ("final_cost", tlrs::util::json::Json::Num(final_cost)),
        ("final_lower_bound", tlrs::util::json::Json::Num(final_lb)),
        (
            "incremental_vs_scratch_speedup",
            tlrs::util::json::Json::Num(speedup),
        ),
        (
            "results",
            tlrs::util::json::Json::Arr(vec![incremental.to_json(), scratch.to_json()]),
        ),
    ]);
    let path = "BENCH_session.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_session.json");
    println!("wrote {path}");
}
