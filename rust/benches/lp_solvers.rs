//! LP-solver benchmarks: native sparse-operator PDHG vs the AOT
//! JAX/Pallas artifact vs exact simplex — the paper's section VI-E
//! "LP solver takes about 15 min" line item, reproduced at seconds scale.

use std::time::Duration;

use tlrs::io::synth::{generate, SynthParams};
use tlrs::lp::pdhg::Operator;
use tlrs::lp::solver::{MappingSolver, NativePdhgSolver, SimplexSolver};
use tlrs::lp::{scaling, MappingLp};
use tlrs::model::trim;
use tlrs::runtime::ArtifactSolver;
use tlrs::util::bench::{bench, bench_n};

fn lp_for(n: usize, m: usize, dims: usize, horizon: u32, seed: u64) -> MappingLp {
    let inst = generate(&SynthParams { n, m, dims, horizon, ..Default::default() }, seed);
    let mut lp = MappingLp::from_instance(&trim(&inst).instance);
    scaling::equilibrate(&mut lp);
    lp
}

fn main() {
    println!("== LP solver benches ==");

    // operator micro-benches: the per-iteration cost
    for &(n, t) in &[(1000usize, 24u32), (2000, 256)] {
        let lp = lp_for(n, 10, 5, t, 1);
        let mut op = Operator::new(&lp);
        let x = vec![0.1; lp.n * lp.m];
        let alpha = vec![0.5; lp.m];
        let y = vec![0.1; lp.m * lp.t * lp.dims];
        let mut kx = vec![0.0; lp.m * lp.t * lp.dims];
        let mut gx = vec![0.0; lp.n * lp.m];
        let mut ga = vec![0.0; lp.m];
        bench(&format!("operator_forward/n={n},T={t}"), Duration::from_millis(500), || {
            op.forward(&x, &alpha, &mut kx)
        });
        bench(&format!("operator_adjoint/n={n},T={t}"), Duration::from_millis(500), || {
            op.adjoint(&y, &mut gx, &mut ga)
        });
    }

    // full solves (paper default scale)
    let lp = lp_for(1000, 10, 5, 24, 2);
    bench_n("pdhg_native/n=1000,m=10,D=5,T=24", 3, || {
        NativePdhgSolver::default().solve_mapping(&lp).unwrap()
    });

    if let Ok(artifact) = ArtifactSolver::from_default_dir() {
        bench_n("pdhg_artifact/n=1000,m=10,D=5,T=24", 3, || {
            artifact.solve_mapping(&lp).unwrap()
        });
    } else {
        println!("(artifacts not built; skipping artifact solver bench)");
    }

    // exact simplex on the largest size it can stomach
    let small = lp_for(30, 3, 2, 8, 3);
    bench_n("simplex_exact/n=30,m=3,D=2", 3, || {
        SimplexSolver.solve_mapping(&small).unwrap()
    });

    // trace-scale native solve (artifact buckets don't reach this T)
    let trace = tlrs::io::gct_like::generate_trace(4000, 4);
    let gct = trace.sample_scenario(1000, 10, 1);
    let mut lp = MappingLp::from_instance(&trim(&gct).instance);
    scaling::equilibrate(&mut lp);
    bench_n(&format!("pdhg_native/gct n=1000 T={}", lp.t), 2, || {
        NativePdhgSolver::default().solve_mapping(&lp).unwrap()
    });
}
