//! L3 placement-engine benchmarks: the hot path of both algorithms
//! (paper section III, Time Complexity; section VI-E running times).
//!
//! Measures the indexed segment-tree path against the seed's dense
//! reference *in the same run* across n and T sweeps, and writes the
//! results to `BENCH_placement.json` so the perf trajectory is tracked
//! PR over PR. `TLRS_BENCH_QUICK=1` shrinks the budgets for the
//! `scripts/tier1.sh` smoke run.

use std::time::Duration;

use tlrs::algo::decompose::{parse_decompose, solve_decomposed};
use tlrs::algo::fill::solve_with_filling;
use tlrs::algo::penalty_map::{map_tasks, MappingPolicy};
use tlrs::algo::pipeline::parse_portfolio;
use tlrs::algo::placement::FitPolicy;
use tlrs::algo::twophase::{
    solve_with_mapping, solve_with_mapping_ref, solve_with_mapping_scan,
    solve_with_mapping_sequential,
};
use tlrs::io::synth::{generate, SynthParams};
use tlrs::lp::solver::{MappingSolver, NativePdhgSolver};
use tlrs::model::trim;
use tlrs::util::bench::{bench, bench_n, fmt_ns, BenchResult};
use tlrs::util::json::Json;

fn main() {
    println!("== placement benches ==");
    let quick = std::env::var("TLRS_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let budget = if quick { Duration::from_millis(60) } else { Duration::from_millis(800) };
    let gct_budget = if quick { Duration::from_millis(300) } else { Duration::from_secs(3) };
    let mut results: Vec<BenchResult> = Vec::new();

    for &n in &[250usize, 1000, 4000] {
        let inst = generate(&SynthParams { n, ..Default::default() }, 1);
        let tr = trim(&inst).instance;
        let mapping = map_tasks(&tr, MappingPolicy::HAvg);

        results.push(bench(&format!("first_fit/n={n}"), budget, || {
            solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false)
        }));
        results.push(bench(&format!("similarity_fit/n={n}"), budget, || {
            solve_with_mapping(&tr, &mapping, FitPolicy::SimilarityFit, false)
        }));
        results.push(bench(&format!("cross_fill/n={n}"), budget, || {
            solve_with_filling(&tr, &mapping, FitPolicy::FirstFit)
        }));
    }

    // mapping phase alone (O(n*m*D))
    let inst = generate(&SynthParams { n: 4000, ..Default::default() }, 2);
    let tr = trim(&inst).instance;
    results.push(bench("penalty_mapping/n=4000", budget, || {
        map_tasks(&tr, MappingPolicy::HAvg)
    }));

    // mixed-pattern point: a non-uniform timeline (bursts, batch windows,
    // duty cycles) from the workload registry, so BENCH numbers cover the
    // shaped loads the pattern families generate
    let mixed = tlrs::io::workload::parse_workload(
        "mixed:services=300,m=6,dims=5,horizon=168",
    )
    .expect("registered family")
    .generate(4)
    .expect("feasible mixed workload");
    let mixed = trim(&mixed).instance;
    let n_mixed = mixed.n_tasks();
    let mapping = map_tasks(&mixed, MappingPolicy::HAvg);
    results.push(bench(&format!("first_fit/mixed n={n_mixed}"), budget, || {
        solve_with_mapping(&mixed, &mapping, FitPolicy::FirstFit, false)
    }));
    results.push(bench(&format!("cross_fill/mixed n={n_mixed}"), budget, || {
        solve_with_filling(&mixed, &mapping, FitPolicy::FirstFit)
    }));

    // shaped-demand point (piecewise profiles): the same diurnal workload
    // expressed as first-class demand segments vs. split into one flat
    // task per segment (the pre-profile workaround, which inflates n and
    // hides within-task reuse from the mapper). Both solve end-to-end.
    let shaped = tlrs::io::workload::parse_workload(
        "mixed:services=300,m=6,dims=5,horizon=168,shape=diurnal",
    )
    .expect("registered family")
    .generate(4)
    .expect("feasible shaped workload");
    let shaped_tr = trim(&shaped).instance;
    let mut next_id = 0u64;
    let split_tasks: Vec<tlrs::model::Task> = shaped_tr
        .tasks
        .iter()
        .flat_map(|t| {
            t.segments().iter().map(|seg| {
                let id = next_id;
                next_id += 1;
                tlrs::model::Task::new(id, seg.demand.clone(), seg.start, seg.end)
            })
            .collect::<Vec<_>>()
        })
        .collect();
    let split = tlrs::model::Instance::new(
        split_tasks,
        shaped_tr.node_types.clone(),
        shaped_tr.horizon,
    );
    let (n_shaped, n_split) = (shaped_tr.n_tasks(), split.n_tasks());
    let shaped_mapping = map_tasks(&shaped_tr, MappingPolicy::HAvg);
    let split_mapping = map_tasks(&split, MappingPolicy::HAvg);
    let shaped_bench = bench(
        &format!("first_fit/shaped segments n={n_shaped}"),
        budget,
        || solve_with_mapping(&shaped_tr, &shaped_mapping, FitPolicy::FirstFit, false),
    );
    let split_bench = bench(
        &format!("first_fit/shaped flat-split n={n_split}"),
        budget,
        || solve_with_mapping(&split, &split_mapping, FitPolicy::FirstFit, false),
    );
    let shaped_speedup = split_bench.mean_ns / shaped_bench.mean_ns;
    println!(
        "shaped first-fit: segments ({n_shaped} tasks) {} vs flat-split \
         ({n_split} tasks) {} -> {shaped_speedup:.2}x",
        fmt_ns(shaped_bench.mean_ns),
        fmt_ns(split_bench.mean_ns)
    );

    // T sweep: same workload over a growing (untrimmed) timeline.
    // Three variants so the index win is separable from threading:
    // indexed (production: parallel), indexed-seq (one thread), dense
    // (the seed, one thread).
    for &t in &[64u32, 512, 4096] {
        let inst = generate(&SynthParams { n: 1000, horizon: t, ..Default::default() }, 7);
        let mapping = map_tasks(&inst, MappingPolicy::HAvg);
        results.push(bench(&format!("first_fit/indexed T={t}"), budget, || {
            solve_with_mapping(&inst, &mapping, FitPolicy::FirstFit, false)
        }));
        results.push(bench(&format!("first_fit/indexed-seq T={t}"), budget, || {
            solve_with_mapping_sequential(&inst, &mapping, FitPolicy::FirstFit)
        }));
        results.push(bench(&format!("first_fit/dense T={t}"), budget, || {
            solve_with_mapping_ref(&inst, &mapping, FitPolicy::FirstFit)
        }));
    }

    // GCT-like shape: long trimmed timeline (week at 5-minute slots;
    // trimmed as every production solve path does), the acceptance
    // comparison for the indexed placement core
    let n_gct = if quick { 600 } else { 2000 };
    let trace = tlrs::io::gct_like::generate_trace(4000, 3);
    let gct = trim(&trace.sample_scenario(n_gct, 13, 1)).instance;
    let t_gct = gct.horizon;
    let mapping = map_tasks(&gct, MappingPolicy::HAvg);
    let indexed = bench(
        &format!("first_fit/gct indexed n={n_gct} T={t_gct}"),
        gct_budget,
        || solve_with_mapping(&gct, &mapping, FitPolicy::FirstFit, false),
    );
    let indexed_seq = bench(
        &format!("first_fit/gct indexed-seq n={n_gct} T={t_gct}"),
        gct_budget,
        || solve_with_mapping_sequential(&gct, &mapping, FitPolicy::FirstFit),
    );
    let dense = bench(
        &format!("first_fit/gct dense n={n_gct} T={t_gct}"),
        gct_budget,
        || solve_with_mapping_ref(&gct, &mapping, FitPolicy::FirstFit),
    );
    let speedup = dense.mean_ns / indexed.mean_ns;
    let speedup_seq = dense.mean_ns / indexed_seq.mean_ns;
    println!(
        "gct first-fit speedup: {speedup:.2}x total, {speedup_seq:.2}x index-only \
         (dense {} -> indexed {})",
        fmt_ns(dense.mean_ns),
        fmt_ns(indexed.mean_ns)
    );
    results.push(indexed);
    results.push(indexed_seq);
    results.push(dense);
    results.push(shaped_bench);
    results.push(split_bench);

    // hot-path lever A/B at a fixed moderate n, all single-threaded so
    // the deltas are separable:
    //   dense    -> scan        isolates the SoA segment-tree store
    //   scan     -> indexed-seq isolates the bucketed-headroom index
    let n_ab = if quick { 2_000 } else { 8_000 };
    let ab = generate(&SynthParams { n: n_ab, ..Default::default() }, 21);
    let ab = trim(&ab).instance;
    let ab_mapping = map_tasks(&ab, MappingPolicy::HAvg);
    let ab_dense = bench(&format!("first_fit/ab dense n={n_ab}"), gct_budget, || {
        solve_with_mapping_ref(&ab, &ab_mapping, FitPolicy::FirstFit)
    });
    let ab_scan = bench(&format!("first_fit/ab scan n={n_ab}"), gct_budget, || {
        solve_with_mapping_scan(&ab, &ab_mapping, FitPolicy::FirstFit)
    });
    let ab_indexed = bench(&format!("first_fit/ab indexed n={n_ab}"), gct_budget, || {
        solve_with_mapping_sequential(&ab, &ab_mapping, FitPolicy::FirstFit)
    });
    let soa_speedup = ab_dense.mean_ns / ab_scan.mean_ns;
    let index_speedup = ab_scan.mean_ns / ab_indexed.mean_ns;
    println!(
        "levers at n={n_ab}: SoA segment store {soa_speedup:.2}x over dense, \
         bucketed index {index_speedup:.2}x over scan (dense {} -> scan {} -> indexed {})",
        fmt_ns(ab_dense.mean_ns),
        fmt_ns(ab_scan.mean_ns),
        fmt_ns(ab_indexed.mean_ns)
    );
    results.push(ab_dense);
    results.push(ab_scan);
    results.push(ab_indexed);

    // decomposed vs monolithic, n sweep up to 10^6. The penalty-based
    // portfolio keeps both arms LP-free (a mapping LP at n=10^6 is the
    // memory wall the decomposition exists to avoid), so the comparison
    // isolates the partition fan-out + stitch against one monolithic
    // two-phase solve over the identical instance.
    let portfolio = parse_portfolio("penalty-map").expect("preset");
    let factory: &(dyn Fn() -> Box<dyn MappingSolver> + Sync) =
        &|| Box::new(NativePdhgSolver::default());
    let sweep: &[usize] = if quick { &[2_000, 20_000] } else { &[10_000, 100_000, 1_000_000] };
    let mut decomposed_speedup = 0.0f64;
    let mut decomposed_norm_cost = 0.0f64;
    let mut sweep_rows: Vec<Json> = Vec::new();
    for &n in sweep {
        let samples = if n >= 100_000 { 1 } else { 3 };
        let inst = generate(&SynthParams { n, m: 5, ..Default::default() }, 31);
        let tr = trim(&inst).instance;
        let solver = NativePdhgSolver::default();
        let mono = bench_n(&format!("solve/monolithic n={n}"), samples, || {
            portfolio.run_sequential(&tr, &solver).expect("monolithic solve")
        });
        let spec = parse_decompose("window:16").expect("spec");
        let deco = bench_n(&format!("solve/decomposed window:16 n={n}"), samples, || {
            solve_decomposed(&tr, &portfolio, factory, &spec).expect("decomposed solve")
        });
        // correctness gate on the artifact numbers: the decomposed plan
        // must verify and respect its own certificate at every point
        let rep = solve_decomposed(&tr, &portfolio, factory, &spec).expect("decomposed solve");
        rep.solution.verify(&tr).expect("decomposed solution verifies");
        assert!(
            rep.certified_lb <= rep.cost + 1e-6 * (1.0 + rep.cost),
            "certified lb {} above cost {}",
            rep.certified_lb,
            rep.cost
        );
        let speedup = mono.mean_ns / deco.mean_ns;
        let norm = rep.cost / rep.certified_lb.max(1e-12);
        println!(
            "decomposed n={n}: {speedup:.2}x over monolithic (mono {} -> deco {}), \
             cost {:.2} vs certified lb {:.2} ({norm:.3}x), stitch saved {:.2}%",
            fmt_ns(mono.mean_ns),
            fmt_ns(deco.mean_ns),
            rep.cost,
            rep.certified_lb,
            100.0 * (rep.pre_stitch_cost - rep.cost) / rep.pre_stitch_cost.max(1e-12)
        );
        sweep_rows.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("monolithic_ns", Json::Num(mono.mean_ns)),
            ("decomposed_ns", Json::Num(deco.mean_ns)),
            ("speedup", Json::Num(speedup)),
            ("cost", Json::Num(rep.cost)),
            ("certified_lb", Json::Num(rep.certified_lb)),
            ("normalized_cost", Json::Num(norm)),
            ("pre_stitch_cost", Json::Num(rep.pre_stitch_cost)),
            ("partitions", Json::Num(rep.partitions.len() as f64)),
        ]));
        decomposed_speedup = speedup;
        decomposed_norm_cost = norm;
        results.push(mono);
        results.push(deco);
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("placement".into())),
        ("quick", Json::Bool(quick)),
        ("gct_n", Json::Num(n_gct as f64)),
        ("gct_horizon", Json::Num(t_gct as f64)),
        ("gct_first_fit_speedup", Json::Num(speedup)),
        ("gct_first_fit_speedup_index_only", Json::Num(speedup_seq)),
        ("shaped_n_segments_tasks", Json::Num(n_shaped as f64)),
        ("shaped_n_split_tasks", Json::Num(n_split as f64)),
        ("shaped_vs_flat_split_speedup", Json::Num(shaped_speedup)),
        ("soa_segment_store_speedup", Json::Num(soa_speedup)),
        ("bucketed_index_speedup", Json::Num(index_speedup)),
        ("decomposed_max_n", Json::Num(*sweep.last().unwrap() as f64)),
        ("decomposed_vs_monolithic_speedup", Json::Num(decomposed_speedup)),
        ("decomposed_normalized_cost", Json::Num(decomposed_norm_cost)),
        ("decomposed_sweep", Json::Arr(sweep_rows)),
        (
            "results",
            Json::Arr(results.iter().map(BenchResult::to_json).collect()),
        ),
    ]);
    let path = "BENCH_placement.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_placement.json");
    println!("wrote {path}");
}
