//! L3 placement-engine benchmarks: the O(n·|S|·D·T) hot path of both
//! algorithms (paper section III, Time Complexity). Regenerates the
//! placement-side of the section VI-E running-time discussion.

use std::time::Duration;

use tlrs::algo::fill::solve_with_filling;
use tlrs::algo::penalty_map::{map_tasks, MappingPolicy};
use tlrs::algo::placement::FitPolicy;
use tlrs::algo::twophase::solve_with_mapping;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::model::trim;
use tlrs::util::bench::bench;

fn main() {
    println!("== placement benches ==");
    let budget = Duration::from_millis(800);

    for &n in &[250usize, 1000, 4000] {
        let inst = generate(&SynthParams { n, ..Default::default() }, 1);
        let tr = trim(&inst).instance;
        let mapping = map_tasks(&tr, MappingPolicy::HAvg);

        bench(&format!("first_fit/n={n}"), budget, || {
            solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false)
        });
        bench(&format!("similarity_fit/n={n}"), budget, || {
            solve_with_mapping(&tr, &mapping, FitPolicy::SimilarityFit, false)
        });
        bench(&format!("cross_fill/n={n}"), budget, || {
            solve_with_filling(&tr, &mapping, FitPolicy::FirstFit)
        });
    }

    // mapping phase alone (O(n*m*D))
    let inst = generate(&SynthParams { n: 4000, ..Default::default() }, 2);
    let tr = trim(&inst).instance;
    bench("penalty_mapping/n=4000", budget, || {
        map_tasks(&tr, MappingPolicy::HAvg)
    });

    // GCT-like shape: long trimmed timeline
    let trace = tlrs::io::gct_like::generate_trace(4000, 3);
    let gct = trace.sample_scenario(2000, 13, 1);
    let tr = trim(&gct).instance;
    let mapping = map_tasks(&tr, MappingPolicy::HAvg);
    bench(
        &format!("first_fit/gct n=2000 T={}", tr.horizon),
        Duration::from_secs(3),
        || solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false),
    );
}
