//! Parallel PDHG engine bench: fixed-iteration solves of one large
//! shaped mapping LP at 1/2/4/8 worker threads (bit-identical results,
//! so the comparison is pure wall-clock), plus the parallel ratio-table
//! build. Writes `BENCH_lp.json` with `parallel_lp_speedup` (serial
//! time over the best parallel time) so the perf trajectory is tracked
//! PR over PR. `TLRS_BENCH_QUICK=1` shrinks the instance and budgets
//! for the tier-1 smoke.

use tlrs::io::workload;
use tlrs::lp::{pdhg, scaling, MappingLp, PdhgOptions};
use tlrs::model::trim;
use tlrs::util::bench::bench_n;
use tlrs::util::json::Json;

fn main() {
    let quick = std::env::var("TLRS_BENCH_QUICK")
        .map(|v| v == "1")
        .unwrap_or(false);
    let (n, iters, samples) = if quick { (4_000, 200, 1) } else { (100_000, 600, 2) };
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!("== parallel LP benches (n={n}, {iters} iters, {cores} cores) ==");

    let spec = format!("synth:n={n},m=6,dims=3,horizon=24,shape=ramp");
    let inst = workload::parse_workload(&spec)
        .expect("workload spec")
        .generate(1)
        .expect("generate");
    let tr = trim(&inst).instance;

    // ratio-table build: serial vs parallel
    let build_serial = bench_n("lp_build/serial", samples.max(2), || {
        MappingLp::from_instance(&tr)
    });
    let build_par = bench_n("lp_build/threads=4", samples.max(2), || {
        MappingLp::from_instance_par(&tr, 4)
    });
    let build_speedup = build_serial.mean_ns / build_par.mean_ns.max(1.0);

    let mut lp = MappingLp::from_instance(&tr);
    scaling::equilibrate(&mut lp);

    // fixed-iteration solves: identical work (and bit-identical output)
    // at every thread count, so wall-clock ratios are the whole story
    let mut results = vec![build_serial, build_par];
    let mut rows = Vec::new();
    let mut serial_ns = 0.0f64;
    let mut best_par_ns = f64::INFINITY;
    let mut objective_bits: Option<u64> = None;
    for threads in [1usize, 2, 4, 8] {
        let opts = PdhgOptions { max_iters: iters, threads, ..Default::default() };
        let mut last_obj = 0.0f64;
        let r = bench_n(&format!("pdhg_solve/threads={threads}"), samples, || {
            let out = pdhg::solve(&lp, &opts);
            last_obj = out.objective;
            out
        });
        // cross-thread-count determinism: the engine's core contract
        match objective_bits {
            None => objective_bits = Some(last_obj.to_bits()),
            Some(bits) => assert_eq!(
                bits,
                last_obj.to_bits(),
                "threads={threads} changed the objective bits"
            ),
        }
        if threads == 1 {
            serial_ns = r.mean_ns;
        } else {
            best_par_ns = best_par_ns.min(r.mean_ns);
        }
        rows.push(Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("mean_ns", Json::Num(r.mean_ns)),
        ]));
        results.push(r);
    }
    let speedup = serial_ns / best_par_ns.max(1.0);
    println!(
        "parallel_lp_speedup: {speedup:.2}x (serial {:.2}ms, best parallel {:.2}ms)",
        serial_ns / 1e6,
        best_par_ns / 1e6
    );
    if !quick && cores >= 2 {
        // on a multi-core box the parallel engine must never lose to
        // the serial path (single-core machines can only measure the
        // dispatch overhead, so the gate is skipped there)
        assert!(speedup >= 1.0, "parallel engine slower than serial: {speedup:.3}x");
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("lp".into())),
        ("quick", Json::Bool(quick)),
        ("cores", Json::Num(cores as f64)),
        ("n", Json::Num(n as f64)),
        ("solve_iters", Json::Num(iters as f64)),
        ("parallel_lp_speedup", Json::Num(speedup)),
        ("builder_build_speedup", Json::Num(build_speedup)),
        ("solves", Json::Arr(rows)),
        ("results", Json::Arr(results.iter().map(|r| r.to_json()).collect())),
    ]);
    let path = "BENCH_lp.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_lp.json");
    println!("wrote {path}");
}
