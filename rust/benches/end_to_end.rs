//! End-to-end benchmarks: full planner evaluations (all four algorithms +
//! lower bound) and single planning-service requests — the numbers behind
//! EXPERIMENTS.md section Perf and the section VI-E reproduction.

use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::coordinator::service::handle_request;
use tlrs::io::files;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::util::bench::bench_n;
use tlrs::util::json::Json;

fn main() {
    println!("== end-to-end benches ==");

    let planner = Planner::new(Backend::Auto).unwrap();

    // paper-default synthetic scenario
    let inst = generate(&SynthParams::default(), 1);
    bench_n("planner_evaluate/synth n=1000,m=10,D=5", 3, || {
        planner.evaluate(&inst).unwrap()
    });

    // GCT-like scenario (long timeline -> native backend)
    let trace = tlrs::io::gct_like::generate_trace(4000, 5);
    let mut gct = trace.sample_scenario(1000, 10, 1);
    tlrs::model::CostModel::homogeneous(gct.dims()).apply(&mut gct.node_types);
    bench_n("planner_evaluate/gct n=1000,m=10", 3, || {
        planner.evaluate(&gct).unwrap()
    });

    // single service request (lp-map-f), via the same codepath as TCP
    let small = generate(&SynthParams { n: 200, m: 5, ..Default::default() }, 2);
    let req = Json::obj(vec![
        ("instance", files::instance_to_json(&small)),
        ("algorithm", Json::Str("lp-map-f".into())),
    ])
    .to_string();
    bench_n("service_request/lp-map-f n=200", 5, || handle_request(&planner, &req));

    bench_n("service_request/penalty-map-f n=200", 5, || {
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&small)),
            ("algorithm", Json::Str("penalty-map-f".into())),
        ])
        .to_string();
        handle_request(&planner, &req)
    });

    println!("\n--- planner metrics ---\n{}", planner.metrics.report());
}
