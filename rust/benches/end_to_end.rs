//! End-to-end benchmarks: full planner evaluations (preset portfolio +
//! lower bound), single planning-service requests, and the parallel
//! portfolio race vs the sequential best-of-4 fold — the numbers behind
//! EXPERIMENTS.md section Perf and the section VI-E reproduction.
//!
//! Writes `BENCH_pipeline.json` (same schema conventions as
//! `BENCH_placement.json`) so the portfolio-racing speedup is tracked
//! PR over PR. `TLRS_BENCH_QUICK=1` shrinks the workload for smoke runs.

use tlrs::algo::pipeline::Portfolio;
use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::coordinator::service::handle_request;
use tlrs::io::files;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::trim;
use tlrs::util::bench::{bench_n, fmt_ns, BenchResult};
use tlrs::util::json::Json;

fn main() {
    println!("== end-to-end benches ==");
    let quick = std::env::var("TLRS_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let samples = if quick { 2 } else { 3 };
    let mut results: Vec<BenchResult> = Vec::new();

    let planner = Planner::new(Backend::Auto).unwrap();

    // paper-default synthetic scenario
    let n_synth = if quick { 400 } else { 1000 };
    let inst = generate(&SynthParams { n: n_synth, ..Default::default() }, 1);
    results.push(bench_n(
        &format!("planner_evaluate/synth n={n_synth},m=10,D=5"),
        samples,
        || planner.evaluate(&inst).unwrap(),
    ));

    // GCT-like scenario (long timeline -> native backend)
    let n_gct = if quick { 400 } else { 1000 };
    let trace = tlrs::io::gct_like::generate_trace(4000, 5);
    let mut gct = trace.sample_scenario(n_gct, 10, 1);
    tlrs::model::CostModel::homogeneous(gct.dims()).apply(&mut gct.node_types);
    results.push(bench_n(&format!("planner_evaluate/gct n={n_gct},m=10"), samples, || {
        planner.evaluate(&gct).unwrap()
    }));

    // parallel portfolio race vs sequential best-of-4 fold: identical
    // work (one shared LP solve + four preset placements) with and
    // without the scoped-thread race.
    let solver = NativePdhgSolver::default();
    let tr = trim(&inst).instance;
    let parallel = bench_n(
        &format!("portfolio/parallel-race n={n_synth}"),
        samples,
        || Portfolio::presets().run(&tr, &solver).unwrap(),
    );
    let sequential = bench_n(
        &format!("portfolio/sequential-fold n={n_synth}"),
        samples,
        || Portfolio::presets().run_sequential(&tr, &solver).unwrap(),
    );
    let gct_tr = trim(&gct).instance;
    let parallel_gct = bench_n(
        &format!("portfolio/parallel-race gct n={n_gct}"),
        samples,
        || Portfolio::presets().run(&gct_tr, &solver).unwrap(),
    );
    let sequential_gct = bench_n(
        &format!("portfolio/sequential-fold gct n={n_gct}"),
        samples,
        || Portfolio::presets().run_sequential(&gct_tr, &solver).unwrap(),
    );
    let speedup = sequential.mean_ns / parallel.mean_ns;
    let speedup_gct = sequential_gct.mean_ns / parallel_gct.mean_ns;
    println!(
        "portfolio race speedup: {speedup:.2}x synth, {speedup_gct:.2}x gct \
         (sequential {} -> parallel {})",
        fmt_ns(sequential.mean_ns),
        fmt_ns(parallel.mean_ns)
    );

    // single service request (lp-map-f), via the same codepath as TCP
    let small = generate(&SynthParams { n: 200, m: 5, ..Default::default() }, 2);
    for algo in ["lp-map-f", "penalty-map-f", "lp+fill+ls"] {
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&small)),
            ("algorithm", Json::Str(algo.into())),
        ])
        .to_string();
        results.push(bench_n(&format!("service_request/{algo} n=200"), 5, || {
            handle_request(&planner, &req)
        }));
    }

    results.push(parallel);
    results.push(sequential);
    results.push(parallel_gct);
    results.push(sequential_gct);

    let json = Json::obj(vec![
        ("bench", Json::Str("pipeline".into())),
        ("quick", Json::Bool(quick)),
        ("synth_n", Json::Num(n_synth as f64)),
        ("gct_n", Json::Num(n_gct as f64)),
        ("portfolio_race_speedup", Json::Num(speedup)),
        ("portfolio_race_speedup_gct", Json::Num(speedup_gct)),
        (
            "results",
            Json::Arr(results.iter().map(BenchResult::to_json).collect()),
        ),
    ]);
    let path = "BENCH_pipeline.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_pipeline.json");
    println!("wrote {path}");

    println!("\n--- planner metrics ---\n{}", planner.metrics.report());
}
