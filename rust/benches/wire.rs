//! Wire-layer benchmarks: the streaming pull-parse/direct-write hot
//! paths against the DOM they replace, on service-scale payloads — a
//! large instance document and a delta stream — reporting bytes/sec and
//! exact allocation counts (via a counting global allocator).
//!
//! Writes `BENCH_wire.json` with `streaming_vs_dom_speedup` (DOM
//! instance-parse mean over streaming mean) plus per-path allocation
//! counts, so the zero-alloc claim is a tracked number, not prose.
//! `TLRS_BENCH_QUICK=1` shrinks the payloads for the tier-1 smoke.

use std::alloc::{GlobalAlloc, Layout, System};
use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use tlrs::io::delta::{delta_from_json, delta_from_slice};
use tlrs::io::files;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::util::bench::{bench, BenchResult};
use tlrs::util::json::{self, Json};

/// Counts every allocation the process makes; the deltas around a
/// single measured call give exact per-operation numbers.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// (allocations, bytes) performed by one call of `f`.
fn count_allocs<T>(f: impl FnOnce() -> T) -> (u64, u64, T) {
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let b0 = ALLOC_BYTES.load(Ordering::Relaxed);
    let out = black_box(f());
    (
        ALLOCS.load(Ordering::Relaxed) - a0,
        ALLOC_BYTES.load(Ordering::Relaxed) - b0,
        out,
    )
}

fn mib_per_s(bytes: usize, mean_ns: f64) -> f64 {
    bytes as f64 / (mean_ns / 1e9) / (1024.0 * 1024.0)
}

fn main() {
    println!("== wire benches ==");
    let quick = std::env::var("TLRS_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let (n_tasks, n_deltas) = if quick { (10_000, 1_000) } else { (100_000, 10_000) };
    let budget = Duration::from_millis(if quick { 250 } else { 1500 });

    // ---- payloads --------------------------------------------------------
    let inst = generate(&SynthParams { n: n_tasks, m: 4, ..Default::default() }, 11);
    let inst_text = files::instance_to_wire_string(&inst);
    let delta_lines: Vec<String> = (0..n_deltas)
        .map(|i| match i % 3 {
            0 => format!(
                "{{\"op\":\"admit\",\"tasks\":[{{\"id\":{},\"start\":2,\"end\":9,\
                 \"demand\":[0.5,0.25,0.1,0.9]}}]}}",
                1_000_000 + i
            ),
            1 => format!("{{\"op\":\"reshape\",\"id\":{},\"demand\":[0.7,0.2,0.4,0.1],\"start\":1,\"end\":7}}", i % n_tasks),
            _ => format!("{{\"op\":\"retire\",\"ids\":[{}]}}", 1_000_000 + i - 2),
        })
        .collect();
    let delta_bytes: usize = delta_lines.iter().map(|l| l.len()).sum();
    println!(
        "payloads: {n_tasks}-task instance ({} bytes), {n_deltas}-delta stream ({delta_bytes} bytes)",
        inst_text.len()
    );

    let report = |r: &BenchResult, bytes: usize| {
        println!("{}  ({:.1} MiB/s)", r.report_line(), mib_per_s(bytes, r.mean_ns));
    };

    // ---- instance parse: DOM vs streaming --------------------------------
    let dom_parse = bench("wire/instance-parse/dom", budget, || {
        files::instance_from_json(&json::parse(&inst_text).unwrap()).unwrap()
    });
    let stream_parse = bench("wire/instance-parse/streaming", budget, || {
        files::instance_from_slice(inst_text.as_bytes()).unwrap()
    });
    let speedup = dom_parse.mean_ns / stream_parse.mean_ns.max(1e-9);
    report(&dom_parse, inst_text.len());
    report(&stream_parse, inst_text.len());

    // ---- instance serialize: DOM vs direct-write -------------------------
    let dom_write = bench("wire/instance-write/dom", budget, || {
        files::instance_to_json(&inst).to_string()
    });
    let stream_write = bench("wire/instance-write/streaming", budget, || {
        files::instance_to_wire_string(&inst)
    });
    report(&dom_write, inst_text.len());
    report(&stream_write, inst_text.len());

    // ---- delta stream: per-line decode -----------------------------------
    let dom_delta = bench("wire/delta-stream/dom", budget, || {
        delta_lines
            .iter()
            .map(|l| delta_from_json(&json::parse(l).unwrap()).unwrap())
            .count()
    });
    let stream_delta = bench("wire/delta-stream/streaming", budget, || {
        delta_lines
            .iter()
            .map(|l| delta_from_slice(l.as_bytes()).unwrap())
            .count()
    });
    report(&dom_delta, delta_bytes);
    report(&stream_delta, delta_bytes);

    // ---- allocation counts (one call each) -------------------------------
    let (dom_parse_allocs, _, _) =
        count_allocs(|| files::instance_from_json(&json::parse(&inst_text).unwrap()).unwrap());
    let (stream_parse_allocs, _, _) =
        count_allocs(|| files::instance_from_slice(inst_text.as_bytes()).unwrap());
    let (dom_write_allocs, _, _) = count_allocs(|| files::instance_to_json(&inst).to_string());
    let (stream_write_allocs, _, _) = count_allocs(|| files::instance_to_wire_string(&inst));
    let one_delta = &delta_lines[1]; // a reshape: flat task body, no arrays of objects
    let (dom_delta_allocs, _, _) =
        count_allocs(|| delta_from_json(&json::parse(one_delta).unwrap()).unwrap());
    let (stream_delta_allocs, _, _) = count_allocs(|| delta_from_slice(one_delta.as_bytes()).unwrap());
    println!(
        "allocs: instance parse {dom_parse_allocs} dom vs {stream_parse_allocs} streaming; \
         instance write {dom_write_allocs} dom vs {stream_write_allocs} streaming; \
         one delta {dom_delta_allocs} dom vs {stream_delta_allocs} streaming"
    );
    println!("streaming vs dom speedup (instance parse): {speedup:.2}x");

    // the whole point: the streaming paths allocate materially less than
    // the DOM they replace (the DOM builds a node per JSON value)
    assert!(
        stream_parse_allocs < dom_parse_allocs / 2,
        "streaming instance parse should allocate far less than the DOM \
         ({stream_parse_allocs} vs {dom_parse_allocs})"
    );
    assert!(
        stream_delta_allocs < dom_delta_allocs / 2,
        "streaming delta decode should allocate far less than the DOM \
         ({stream_delta_allocs} vs {dom_delta_allocs})"
    );

    let artifact = Json::obj(vec![
        ("bench", Json::Str("wire".into())),
        ("quick", Json::Bool(quick)),
        ("n_tasks", Json::Num(n_tasks as f64)),
        ("n_deltas", Json::Num(n_deltas as f64)),
        ("instance_bytes", Json::Num(inst_text.len() as f64)),
        ("delta_bytes", Json::Num(delta_bytes as f64)),
        ("streaming_vs_dom_speedup", Json::Num(speedup)),
        (
            "instance_parse_mib_per_s",
            Json::obj(vec![
                ("dom", Json::Num(mib_per_s(inst_text.len(), dom_parse.mean_ns))),
                ("streaming", Json::Num(mib_per_s(inst_text.len(), stream_parse.mean_ns))),
            ]),
        ),
        (
            "allocs",
            Json::obj(vec![
                ("instance_parse_dom", Json::Num(dom_parse_allocs as f64)),
                ("instance_parse_streaming", Json::Num(stream_parse_allocs as f64)),
                ("instance_write_dom", Json::Num(dom_write_allocs as f64)),
                ("instance_write_streaming", Json::Num(stream_write_allocs as f64)),
                ("delta_decode_dom", Json::Num(dom_delta_allocs as f64)),
                ("delta_decode_streaming", Json::Num(stream_delta_allocs as f64)),
            ]),
        ),
        (
            "results",
            Json::Arr(
                [dom_parse, stream_parse, dom_write, stream_write, dom_delta, stream_delta]
                    .iter()
                    .map(|r| r.to_json())
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("BENCH_wire.json", artifact.to_string() + "\n").unwrap();
    println!("wrote BENCH_wire.json");
}
