#!/usr/bin/env bash
# Tier-1 gate: release build + full test suite + placement-bench smoke.
#
# The bench smoke runs in quick mode (TLRS_BENCH_QUICK=1) under a time
# budget and leaves rust/BENCH_placement.json behind so the placement
# perf trajectory (indexed vs dense, GCT speedup) is tracked per PR.
#
#   TIER1_BENCH_TIMEOUT   seconds allowed for the bench smoke (default 300)
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo build --release --examples --benches =="
# examples and benches are consumers of the public API: compiling them
# here makes API drift fail the gate instead of rotting silently
cargo build --release --examples --benches

echo "== tier1: cargo test -q =="
cargo test -q

echo "== tier1: workload generator smoke =="
# gen + solve every registered family through the spec parser, so an
# unregistered, panicking or infeasible family fails the gate
TLRS=target/release/tlrs
GEN_DIR=$(mktemp -d)
trap 'rm -rf "$GEN_DIR"' EXIT
"$TLRS" workloads --smoke | while read -r spec; do
    fam="${spec%%:*}"
    echo "-- $spec"
    "$TLRS" gen --workload "$spec" --seed 1 --out "$GEN_DIR/$fam.json"
    "$TLRS" solve --input "$GEN_DIR/$fam.json" --algo lp+fill --backend native \
        > /dev/null
done
N_FAMILIES=$("$TLRS" workloads --names | wc -l)
N_GENERATED=$(ls "$GEN_DIR" | wc -l)
test "$N_FAMILIES" -eq "$N_GENERATED"
echo "smoked $N_GENERATED workload families"

echo "== tier1: placement bench smoke =="
TLRS_BENCH_QUICK=1 timeout "${TIER1_BENCH_TIMEOUT:-300}" \
    cargo bench --bench placement

echo "== tier1: BENCH_placement.json =="
test -f BENCH_placement.json
head -c 400 BENCH_placement.json
echo
echo "== tier1 OK =="
