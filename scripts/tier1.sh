#!/usr/bin/env bash
# Tier-1 gate: release build + full test suite + service-runtime smoke
# + placement-bench smoke.
#
# The bench smoke runs in quick mode (TLRS_BENCH_QUICK=1) under a time
# budget and leaves rust/BENCH_placement.json behind so the placement
# perf trajectory (indexed vs dense, GCT speedup) is tracked per PR.
#
#   TIER1_BENCH_TIMEOUT   seconds allowed for the bench smoke (default 300)
set -euo pipefail

echo "== tier1: tlrs-lint =="
# the determinism & safety analyzer (docs/INVARIANTS.md) gates first:
# a lint violation is cheaper to report before the full build + suite
"$(dirname "$0")/lint.sh"

cd "$(dirname "$0")/../rust"

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo build --release --examples --benches =="
# examples and benches are consumers of the public API: compiling them
# here makes API drift fail the gate instead of rotting silently
cargo build --release --examples --benches

echo "== tier1: cargo test -q =="
cargo test -q

echo "== tier1: workload generator smoke =="
# gen + solve every registered family through the spec parser — once flat
# and once with a piecewise demand shape — so an unregistered, panicking
# or infeasible family (or a shape regression) fails the gate
TLRS=target/release/tlrs
GEN_DIR=$(mktemp -d)
trap 'rm -rf "$GEN_DIR"' EXIT
# the csv family's smoke spec imports this fixture trace
"$TLRS" gen --workload synth:n=40,m=3,dims=2 --seed 1 \
    --out "$GEN_DIR/csv-fixture.json" --csv target/tlrs-smoke-trace.csv
rm "$GEN_DIR/csv-fixture.json"
"$TLRS" workloads --smoke | while read -r spec; do
    fam="${spec%%:*}"
    echo "-- $spec"
    "$TLRS" gen --workload "$spec" --seed 1 --out "$GEN_DIR/$fam.json"
    "$TLRS" solve --input "$GEN_DIR/$fam.json" --algo lp+fill --backend native \
        > /dev/null
    echo "-- $spec,shape=diurnal"
    "$TLRS" gen --workload "$spec,shape=diurnal" --seed 1 \
        --out "$GEN_DIR/$fam-shaped.json"
    "$TLRS" solve --input "$GEN_DIR/$fam-shaped.json" --algo lp+fill \
        --backend native > /dev/null
done
N_FAMILIES=$("$TLRS" workloads --names | wc -l)
N_GENERATED=$(ls "$GEN_DIR" | grep -v -- -shaped | wc -l)
test "$N_FAMILIES" -eq "$N_GENERATED"
N_SHAPED=$(ls "$GEN_DIR" | grep -c -- -shaped)
test "$N_FAMILIES" -eq "$N_SHAPED"
echo "smoked $N_GENERATED workload families (flat + shaped)"

echo "== tier1: csv trace import round-trip =="
# export a generated trace to CSV, re-import it through the csv family,
# and solve the import — the importer must reproduce the tasks verbatim
"$TLRS" gen --workload synth:n=60,m=4,dims=2 --seed 2 \
    --out "$GEN_DIR/rt-src.json" --csv "$GEN_DIR/rt-trace.csv"
"$TLRS" gen --workload "csv:path=$GEN_DIR/rt-trace.csv,m=4" --seed 2 \
    --out "$GEN_DIR/rt-import.json"
"$TLRS" solve --workload "csv:path=$GEN_DIR/rt-trace.csv,m=4" --seed 2 \
    --algo lp+fill --backend native > /dev/null
rm "$GEN_DIR/rt-src.json" "$GEN_DIR/rt-trace.csv" "$GEN_DIR/rt-import.json"

echo "== tier1: plan-session smoke =="
# open -> admit -> reshape -> retire -> close through the incremental
# session path; --check asserts per-delta cost >= certified LB and an
# independent dense-backend verify of the final state
"$TLRS" gen --workload synth:n=50,m=4,dims=2 --seed 3 --out "$GEN_DIR/sess.json"
cat > "$GEN_DIR/sess-deltas.jsonl" <<'EOF'
# tier1 session smoke: admit two tasks (one piecewise), reshape, retire
{"op":"admit","tasks":[{"id":9001,"demand":[0.08,0.05],"start":0,"end":6},{"id":9002,"segments":[{"start":2,"end":4,"demand":[0.02,0.02]},{"start":5,"end":9,"demand":[0.09,0.04]}],"start":2,"end":9}]}
{"op":"reshape","id":9001,"demand":[0.12,0.1],"start":1,"end":8}
{"op":"reprice","node_types":[]}
{"op":"retire","ids":[9001,9002]}
EOF
# the deliberately-invalid reprice line must fail the stream loader...
if "$TLRS" session --input "$GEN_DIR/sess.json" --deltas "$GEN_DIR/sess-deltas.jsonl" \
    --check > /dev/null 2>&1; then
    echo "session smoke: invalid delta was not rejected"; exit 1
fi
# ...and without it the stream must replay clean
grep -v reprice "$GEN_DIR/sess-deltas.jsonl" > "$GEN_DIR/sess-deltas-ok.jsonl"
"$TLRS" session --input "$GEN_DIR/sess.json" --deltas "$GEN_DIR/sess-deltas-ok.jsonl" \
    --check --escalate 1.5 | tee "$GEN_DIR/sess.out"
grep -q "session check  : OK" "$GEN_DIR/sess.out"
grep -q "retire" "$GEN_DIR/sess.out"

echo "== tier1: parallel LP smoke =="
# the parallel engine is a pure perf knob: a 2-thread solve must replay
# the whole CLI path cleanly (bit-identical results are pinned by
# tests/prop_lp_parallel.rs, run explicitly below)
"$TLRS" solve --input "$GEN_DIR/sess.json" --algo lp-map-f --backend native \
    --lp-threads 2 > /dev/null
cargo test -q --test prop_lp_parallel

echo "== tier1: decomposed solve smoke =="
# one decomposed solve per built-in partitioner: the partition table,
# the stitch line, and the certified combined bound must all print
"$TLRS" gen --workload synth:n=120,m=4,dims=3 --seed 5 --out "$GEN_DIR/deco.json"
for dspec in window:4 dims size:3; do
    echo "-- --decompose $dspec"
    "$TLRS" solve --input "$GEN_DIR/deco.json" --algo penalty-map,penalty-map-f \
        --decompose "$dspec" --backend native | tee "$GEN_DIR/deco.out"
    grep -q "decompose      : $dspec" "$GEN_DIR/deco.out"
    grep -q "partition    :" "$GEN_DIR/deco.out"
    grep -q "lower bound    :" "$GEN_DIR/deco.out"
    grep -q "stitch" "$GEN_DIR/deco.out"
done
# degenerate partition counts are errors, not degenerate solves
if "$TLRS" solve --input "$GEN_DIR/deco.json" --decompose window:0 \
    --backend native > /dev/null 2>&1; then
    echo "decompose smoke: k=0 was not rejected"; exit 1
fi

echo "== tier1: service stress tests =="
# the multi-client runtime tests (concurrent clients, admission/shedding,
# graceful shutdown, budgets) also run under `cargo test -q` above; the
# explicit run keeps the concurrent-runtime coverage visible and
# mandatory even if the suite above is ever filtered
cargo test -q --test stress_service

echo "== tier1: service runtime smoke =="
# boot the real CLI server on an ephemeral port, drive solve -> stats ->
# shutdown over /dev/tcp, and require a clean drain (exit 0)
SRV_LOG="$GEN_DIR/serve.log"
"$TLRS" serve --addr 127.0.0.1:0 --workers 2 --queue 4 --allow-shutdown \
    --backend native > "$SRV_LOG" 2>&1 &
SRV_PID=$!
trap 'rm -rf "$GEN_DIR"; kill "${SRV_PID:-}" 2>/dev/null || true' EXIT
for _ in $(seq 1 100); do
    grep -q "tlrs planning service on" "$SRV_LOG" && break
    sleep 0.1
done
grep -q "tlrs planning service on" "$SRV_LOG"
PORT=$(sed -n 's/.*127\.0\.0\.1:\([0-9]*\).*/\1/p' "$SRV_LOG" | head -1)
exec 3<>"/dev/tcp/127.0.0.1/$PORT"
printf '%s\n' '{"workload":"synth:n=20,m=3,dims=2","seed":4,"algorithm":"penalty-map-f"}' >&3
IFS= read -r RESP <&3
echo "$RESP" | grep -q '"ok":true'
printf '%s\n' '{"op":"stats"}' >&3
IFS= read -r RESP <&3
echo "$RESP" | grep -q 'service_connections_live'
printf '%s\n' '{"op":"shutdown"}' >&3
IFS= read -r RESP <&3
echo "$RESP" | grep -q '"draining":true'
exec 3<&- 3>&-
wait "$SRV_PID"
echo "service runtime smoke: solve/stats/shutdown OK, server drained clean"

echo "== tier1: large-instance solve-over-service smoke =="
# a 20k-task inline instance streamed over one request line exercises
# the wire layer's typed instance decoder at service scale (the request
# is far past any small-buffer path) plus the decomposed solve
"$TLRS" gen --workload synth:n=20000,m=4,dims=2 --seed 6 --out "$GEN_DIR/big.json"
"$TLRS" serve --addr 127.0.0.1:0 --workers 2 --queue 4 --allow-shutdown \
    --backend native > "$SRV_LOG" 2>&1 &
SRV_PID=$!
for _ in $(seq 1 100); do
    grep -q "tlrs planning service on" "$SRV_LOG" && break
    sleep 0.1
done
grep -q "tlrs planning service on" "$SRV_LOG"
PORT=$(sed -n 's/.*127\.0\.0\.1:\([0-9]*\).*/\1/p' "$SRV_LOG" | head -1)
exec 3<>"/dev/tcp/127.0.0.1/$PORT"
{ printf '%s' '{"algorithm":"penalty-map-f","decompose":"size:8","instance":'; \
  cat "$GEN_DIR/big.json"; printf '%s\n' '}'; } >&3
IFS= read -r RESP <&3
echo "$RESP" | grep -q '"ok":true'
echo "$RESP" | grep -q '"decompose":"size:8"'
printf '%s\n' '{"op":"shutdown"}' >&3
IFS= read -r RESP <&3
echo "$RESP" | grep -q '"draining":true'
exec 3<&- 3>&-
wait "$SRV_PID"
echo "large-instance service smoke: 20k-task solve OK"

echo "== tier1: session bench smoke =="
TLRS_BENCH_QUICK=1 timeout "${TIER1_BENCH_TIMEOUT:-300}" \
    cargo bench --bench session
test -f BENCH_session.json
head -c 400 BENCH_session.json
echo

echo "== tier1: wire bench smoke =="
# quick-mode run of the streaming-vs-DOM wire benches; the bench itself
# asserts the streaming paths allocate materially less than the DOM
TLRS_BENCH_QUICK=1 timeout "${TIER1_BENCH_TIMEOUT:-300}" \
    cargo bench --bench wire
test -f BENCH_wire.json
head -c 400 BENCH_wire.json
echo

echo "== tier1: parallel LP bench smoke =="
TLRS_BENCH_QUICK=1 timeout "${TIER1_BENCH_TIMEOUT:-300}" \
    cargo bench --bench lp
test -f BENCH_lp.json
head -c 400 BENCH_lp.json
echo

echo "== tier1: placement bench smoke =="
TLRS_BENCH_QUICK=1 timeout "${TIER1_BENCH_TIMEOUT:-300}" \
    cargo bench --bench placement

echo "== tier1: BENCH_placement.json =="
test -f BENCH_placement.json
head -c 400 BENCH_placement.json
echo
echo "== tier1 OK =="
