#!/usr/bin/env bash
# tlrs-lint gate: scan rust/src for determinism & safety invariant
# violations (docs/INVARIANTS.md) and regenerate the unsafe inventory
# (LINT_unsafe.json at the repo root).
#
# Prefers the Rust binary; containers without a Rust toolchain fall
# back to the line-for-line Python mirror — the two are pinned to
# identical verdicts by the shared fixture corpus.
set -euo pipefail
cd "$(dirname "$0")/.."

if command -v cargo > /dev/null 2>&1; then
    echo "== lint: tlrs-lint (rust) =="
    cargo run --quiet --release --manifest-path rust/Cargo.toml --bin tlrs-lint -- \
        --root rust/src --unsafe-out LINT_unsafe.json --quiet
else
    echo "== lint: tlrs-lint (python mirror; no cargo in PATH) =="
    python3 python/tools/lint.py --root rust/src --unsafe-out LINT_unsafe.json --quiet
fi
