#!/usr/bin/env bash
# Full benchmark suite: every bench target in release mode, refreshing
# the rust/BENCH_*.json artifacts that track the perf trajectory PR
# over PR (placement records the decomposed-vs-monolithic sweep up to
# n = 10^6 plus the bucketed-index and SoA-store deltas; service records
# solve throughput/latency through the concurrent runtime at 1/4/16
# clients and the concurrent-vs-sequential speedup; wire records the
# streaming pull-parse/direct-write layer against the DOM it replaces,
# with bytes/sec and exact allocation counts; lp records the parallel
# PDHG engine's 1/2/4/8-thread speedup on one large shaped LP, where
# results are bit-identical so the ratio is pure wall-clock).
#
#   TLRS_BENCH_QUICK=1  shrink budgets to the tier-1 smoke sizes
#   BENCH_ONLY=<name>   run a single bench target (placement, session,
#                       end_to_end, lp_solvers, lp, service, wire)
set -euo pipefail
cd "$(dirname "$0")/../rust"

BENCHES=(placement session end_to_end lp_solvers lp service wire)
if [[ -n "${BENCH_ONLY:-}" ]]; then
    BENCHES=("$BENCH_ONLY")
fi

cargo build --release --benches

for b in "${BENCHES[@]}"; do
    echo "== bench: $b =="
    cargo bench --bench "$b"
done

echo "== BENCH artifacts =="
for f in BENCH_*.json; do
    [[ -f "$f" ]] || continue
    printf '%-28s %s bytes\n' "$f" "$(wc -c < "$f")"
done
