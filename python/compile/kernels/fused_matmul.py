"""L1 Pallas kernel: fused scale-matmul.

The TL-Rightsizing mapping LP's constraint operator never materializes its
(m*T*D) x (n*m) matrix.  Both the forward operator

    K(x)[B,t,d] = sum_u Act[t,u] * x[u,B] * r[u,B,d]

and its adjoint reduce to one primitive: a tiled matmul with an elementwise
scaling fused into the left-operand tiles,

    out = A @ (X * S)

with A:(T,N), X:(N,K), S:(N,K).  The grid tiles the T rows; each grid step
is a (Tt x N) @ (N x K) contraction -- an MXU-native shape on TPU.  The
X*S product is recomputed per tile inside VMEM; its cost (N*K mults) is
negligible against the matmul (Tt*N*K MACs) and fusing it avoids an HBM
round-trip for the scaled operand.

interpret=True: the CPU PJRT plugin cannot execute Mosaic custom-calls, so
the kernel is lowered through the Pallas interpreter into plain HLO.  On a
real TPU the same BlockSpec schedule applies (see DESIGN.md
section Hardware-Adaptation for the VMEM/MXU estimate).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block_rows(t: int) -> int:
    """Largest MXU-friendly tile height that divides t."""
    for cand in (128, 64, 32, 16, 8, 4, 2):
        if t % cand == 0:
            return cand
    return 1


def _kernel(a_ref, x_ref, s_ref, o_ref):
    # Fuse the elementwise scale into the tile, then hit the MXU.
    xs = x_ref[...] * s_ref[...]
    o_ref[...] = jnp.dot(a_ref[...], xs, preferred_element_type=jnp.float32)


def fused_scale_matmul(a, x, s, *, block_rows: int | None = None):
    """Compute ``a @ (x * s)`` with a row-tiled Pallas kernel.

    a: (T, N) float32   left operand (activity mask tiles stream through VMEM)
    x: (N, K) float32   right operand
    s: (N, K) float32   elementwise scale fused into the right operand
    returns (T, K) float32
    """
    t, n = a.shape
    n2, k = x.shape
    assert n == n2, f"contraction mismatch {n} vs {n2}"
    assert s.shape == (n, k), f"scale shape {s.shape} != {(n, k)}"
    br = block_rows or _pick_block_rows(t)
    assert t % br == 0, f"block_rows {br} must divide T {t}"
    return pl.pallas_call(
        _kernel,
        grid=(t // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, k), jnp.float32),
        interpret=True,
    )(a, x, s)


def k_forward(act, x, r):
    """Constraint-operator forward pass.

    act: (T, N) 0/1 activity mask,  x: (N, M) assignment,  r: (N, M, D)
    normalized demand ratios.  Returns (M, T, D):
    K(x)[B,t,d] = sum_u act[t,u] * x[u,B] * r[u,B,d].
    """
    n, m, d = r.shape
    xb = jnp.broadcast_to(x[:, :, None], (n, m, d)).reshape(n, m * d)
    out = fused_scale_matmul(act, xb, r.reshape(n, m * d))  # (T, M*D)
    t = act.shape[0]
    return out.reshape(t, m, d).transpose(1, 0, 2)


def k_adjoint(act, y, r):
    """Constraint-operator adjoint.

    act: (T, N), y: (M, T, D), r: (N, M, D).  Returns (N, M):
    (K^T y)[u,B] = sum_{t,d} act[t,u] * r[u,B,d] * y[B,t,d].
    """
    m, t, d = y.shape
    yflat = y.transpose(1, 0, 2).reshape(t, m * d)
    ones = jnp.ones_like(yflat)
    z = fused_scale_matmul(act.T, yflat, ones)  # (N, M*D)
    n = act.shape[1]
    return jnp.sum(z.reshape(n, m, d) * r, axis=2)
