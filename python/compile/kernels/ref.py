"""Pure-jnp oracles for the Pallas kernels (build-time correctness only).

Every kernel in this package has a reference implementation here, written
with plain jnp/einsum and no Pallas.  pytest + hypothesis assert allclose
between kernel and oracle across shapes and dtypes; the AOT path is only
trusted because this file exists.
"""

import jax.numpy as jnp


def fused_scale_matmul_ref(a, x, s):
    """out = a @ (x * s)."""
    return jnp.dot(a, x * s)


def k_forward_ref(act, x, r):
    """K(x)[B,t,d] = sum_u act[t,u] * x[u,B] * r[u,B,d]."""
    return jnp.einsum("tu,ub,ubd->btd", act, x, r)


def k_adjoint_ref(act, y, r):
    """(K^T y)[u,B] = sum_{t,d} act[t,u] * r[u,B,d] * y[B,t,d]."""
    return jnp.einsum("tu,btd,ubd->ub", act, y, r)


def penalty_avg_ref(dem, capinv, cost):
    """p_avg[u,B] = cost[B]/D * sum_d dem[u,d] * capinv[B,d]."""
    d = dem.shape[1]
    return jnp.einsum("ud,bd->ub", dem, capinv) * cost[None, :] / d


def penalty_max_ref(dem, capinv, cost):
    """p_max[u,B] = cost[B] * max_d dem[u,d] * capinv[B,d]."""
    h = jnp.max(dem[:, None, :] * capinv[None, :, :], axis=2)
    return h * cost[None, :]


def h_avg_ref(dem, capinv):
    """h_avg[u,B] = 1/D * sum_d dem[u,d] * capinv[B,d]."""
    d = dem.shape[1]
    return jnp.einsum("ud,bd->ub", dem, capinv) / d
