"""L1 penalty-scoring kernels for the PenaltyMap mapping phase.

PenaltyMap (paper section III) scores every (task, node-type) pair:

    h_avg(u|B) = 1/D * sum_d dem(u,d)/cap(B,d)        relative demand
    p_avg(u|B) = cost(B) * h_avg(u|B)                 penalty
    h_max(u|B) = max_d  dem(u,d)/cap(B,d)             alternative policy
    p_max(u|B) = cost(B) * h_max(u|B)

The average-variants are (N,D)@(D,M) matmuls and run through the same
fused_scale_matmul Pallas kernel as the LP operator; the max-variant is an
elementwise reduce kept in jnp (no contraction to tile).
"""

import jax.numpy as jnp

from .fused_matmul import fused_scale_matmul


def penalty_scores(dem, capinv, cost):
    """Score all pairs.

    dem:    (N, D) task demands
    capinv: (M, D) reciprocal capacities 1/cap(B,d)
    cost:   (M,)   node-type prices

    Returns (p_avg, p_max, h_avg), each (N, M).
    """
    n, d = dem.shape
    m = cost.shape[0]
    # h_avg = dem @ capinv^T / D, via the fused kernel with scale = 1/D.
    scale = jnp.full((d, m), 1.0 / d, dtype=jnp.float32)
    h_avg = fused_scale_matmul(dem, capinv.T, scale)
    p_avg = h_avg * cost[None, :]
    h_max = jnp.max(dem[:, None, :] * capinv[None, :, :], axis=2)
    p_max = h_max * cost[None, :]
    return p_avg, p_max, h_avg
