"""AOT lowering: JAX/Pallas programs -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (the
version behind the published `xla` rust crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Instances are zero-padded into fixed shape buckets (PJRT needs static
shapes).  Padded tasks have zero demand, an empty activity column and
taskmask 0; padded node-types have rho == 0 rows, typemask 0 and cost 0 --
they are inert in every constraint (see model.py).

Emitted per bucket `k`:
    pdhg_<k>.hlo.txt     one PDHG chunk (warm-startable)
    power_<k>.hlo.txt    ||A||_2 power-iteration estimate
    penalty_<k>.hlo.txt  PenaltyMap scoring (p_avg, p_max, h_avg)
plus a manifest.json the Rust runtime uses for bucket selection.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# (name, N, M, T, D, chunk_iters): shape buckets.  b0 covers unit tests and
# the quickstart; b1 the synthetic benchmark defaults (n=1000, T=24 trimmed,
# D<=8, m<=16); b2 the GCT-like trace (D=2).  Instances whose trimmed T
# exceeds every bucket fall back to the Rust-native sparse-operator PDHG.
BUCKETS = [
    ("b0", 128, 8, 32, 4, 200),
    ("b1", 1024, 16, 32, 8, 100),
    ("b2", 2048, 16, 256, 2, 50),
]

POWER_ITERS = 60


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_bucket(name, n, m, t, d, iters):
    """Lower the three programs for one bucket; returns {fname: hlo_text}."""
    act = _spec(t, n)
    r = _spec(n, m, d)
    rho = _spec(m, t, d)
    c = _spec(m)
    tmask = _spec(n)
    bmask = _spec(m)
    x = _spec(n, m)
    alpha = _spec(m)
    y = _spec(m, t, d)
    w = _spec(n)
    scal = _spec()

    pdhg = jax.jit(M.make_pdhg(iters))
    pdhg_hlo = to_hlo_text(pdhg.lower(
        act, r, rho, c, tmask, bmask, x, alpha, y, w, scal, scal))

    power = jax.jit(lambda a_, r_, rho_: M.power_iter(a_, r_, rho_,
                                                      n_iter=POWER_ITERS))
    power_hlo = to_hlo_text(power.lower(act, r, rho))

    dem = _spec(n, d)
    capinv = _spec(m, d)
    pen = jax.jit(M.penalty_scores)
    pen_hlo = to_hlo_text(pen.lower(dem, capinv, c))

    return {
        f"pdhg_{name}.hlo.txt": pdhg_hlo,
        f"power_{name}.hlo.txt": power_hlo,
        f"penalty_{name}.hlo.txt": pen_hlo,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", default="",
                    help="comma-separated bucket names to build (default all)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    want = set(filter(None, args.buckets.split(",")))
    manifest = {"format": "hlo-text", "power_iters": POWER_ITERS,
                "buckets": []}
    for name, n, m, t, d, iters in BUCKETS:
        if want and name not in want:
            continue
        files = lower_bucket(name, n, m, t, d, iters)
        for fname, text in files.items():
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")
        manifest["buckets"].append({
            "name": name, "n": n, "m": m, "t": t, "d": d,
            "chunk_iters": iters,
            "pdhg": f"pdhg_{name}.hlo.txt",
            "power": f"power_{name}.hlo.txt",
            "penalty": f"penalty_{name}.hlo.txt",
        })
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
