"""L2: the mapping-LP PDHG solver as a JAX compute graph.

The TL-Rightsizing mapping LP (paper section V-B), over padded shapes
(N tasks, M node-types, T timeslots, D dimensions):

    min  sum_B cost[B] * alpha[B]
    s.t. sum_B x[u,B] = taskmask[u]                    (dual w, free)
         rho[B,t,d] * ( K(x)[B,t,d] - alpha[B] ) <= 0  (dual y >= 0)
         x, alpha >= 0

    K(x)[B,t,d] = sum_u act[t,u] * x[u,B] * r[u,B,d]
    r[u,B,d]    = dem(u,d) / cap(B,d)

rho carries both row equilibration (Ruiz scaling, computed in Rust) and
padding masks: rho == 0 on padded (B,t,d) rows removes them.  taskmask
zeroes the equality row of padded tasks; typemask projects x columns of
padded node-types to zero each iteration.

The solver is PDHG (Chambolle-Pock) with iterate averaging; one AOT call
runs a fixed chunk of iterations (lax.fori_loop) and returns both the last
and the chunk-averaged iterates plus residual diagnostics.  The Rust L3
driver chains chunks, restarts from the better iterate (PDLP-style restart)
and retunes the primal weight omega between chunks.  All heavy linear
algebra goes through the L1 Pallas kernel (k_forward / k_adjoint).

This module is build-time only: aot.py lowers `pdhg_chunk`, `power_iter`
and `penalty_scores` to HLO text; the Rust runtime executes the artifacts.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.fused_matmul import k_forward, k_adjoint
from .kernels.penalty import penalty_scores  # re-exported for aot.py

__all__ = ["pdhg_chunk", "power_iter", "penalty_scores", "residuals"]


def _operators(act, r, rho):
    """Masked/scaled forward + adjoint closures."""

    def fwd(x, alpha):
        # rho * (K x - alpha), shape (M, T, D)
        kx = k_forward(act, x, r)
        return rho * (kx - alpha[:, None, None])

    def adj(y):
        # (K^T (rho*y), sum_td rho*y) -- gradient pieces for x and alpha
        ry = rho * y
        return k_adjoint(act, ry, r), jnp.sum(ry, axis=(1, 2))

    return fwd, adj


def residuals(act, r, rho, c, taskmask, x, alpha, y, w):
    """Primal/dual residuals + normalized gap for an iterate.

    Returns a (4,) f32 vector: [eq_res, ineq_res, dual_res, gap].
    """
    fwd, adj = _operators(act, r, rho)
    eq_res = jnp.max(jnp.abs(jnp.sum(x, axis=1) - taskmask))
    ineq_res = jnp.max(jnp.maximum(fwd(x, alpha), 0.0))
    kty, sum_ry = adj(y)
    # Stationarity: for x >= 0 need K^T(rho y) - w >= 0 (violation below 0);
    # for alpha >= 0 need c - sum(rho y) >= 0.
    dual_x = jnp.max(jnp.maximum(w[:, None] - kty, 0.0))
    dual_a = jnp.max(jnp.maximum(sum_ry - c, 0.0))
    dual_res = jnp.maximum(dual_x, dual_a)
    pobj = jnp.dot(c, alpha)
    dobj = jnp.dot(w, taskmask)
    gap = jnp.abs(pobj - dobj) / (1.0 + jnp.abs(pobj) + jnp.abs(dobj))
    return jnp.stack([eq_res, ineq_res, dual_res, gap])


def pdhg_chunk(act, r, rho, c, taskmask, typemask, x0, alpha0, y0, w0,
               tau, sigma, *, n_iter: int):
    """Run `n_iter` PDHG iterations from the given state.

    act:      (T, N)   0/1 activity mask (padded rows/cols zero)
    r:        (N, M, D) demand/capacity ratios (padded entries zero)
    rho:      (M, T, D) row scaling, zero on padded constraint rows
    c:        (M,)     node-type costs (padded types zero)
    taskmask: (N,)     1 for real tasks
    typemask: (M,)     1 for real node-types
    x0,alpha0,y0,w0:   warm-start state
    tau, sigma:        scalar step sizes (tau*sigma*||A||^2 < 1)

    Returns (x, alpha, y, w, xa, alphaa, ya, wa, diag) where the *a values
    are chunk averages and diag is (8,) = residuals(last) ++ residuals(avg).
    """
    fwd, adj = _operators(act, r, rho)

    def body(_, carry):
        x, a, y, w, sx, sa, sy, sw = carry
        kty, sum_ry = adj(y)
        gx = kty - w[:, None]
        ga = c - sum_ry
        xn = jnp.maximum(x - tau * gx, 0.0) * typemask[None, :]
        an = jnp.maximum(a - tau * ga, 0.0) * typemask
        xb = 2.0 * xn - x
        ab = 2.0 * an - a
        yn = jnp.maximum(y + sigma * fwd(xb, ab), 0.0)
        wn = w + sigma * (taskmask - jnp.sum(xb, axis=1))
        return (xn, an, yn, wn, sx + xn, sa + an, sy + yn, sw + wn)

    zx, za = jnp.zeros_like(x0), jnp.zeros_like(alpha0)
    zy, zw = jnp.zeros_like(y0), jnp.zeros_like(w0)
    x, a, y, w, sx, sa, sy, sw = jax.lax.fori_loop(
        0, n_iter, body, (x0, alpha0, y0, w0, zx, za, zy, zw))
    k = jnp.float32(n_iter)
    xa, aa, ya, wa = sx / k, sa / k, sy / k, sw / k
    diag = jnp.concatenate([
        residuals(act, r, rho, c, taskmask, x, a, y, w),
        residuals(act, r, rho, c, taskmask, xa, aa, ya, wa),
    ])
    return x, a, y, w, xa, aa, ya, wa, diag


def power_iter(act, r, rho, *, n_iter: int = 40):
    """Estimate ||A||_2 of the full constraint operator by power iteration.

    A stacks the scaled inequality rows rho*(K x - alpha) and the equality
    rows sum_B x[u,B].  Deterministic start (ones) -- no RNG in artifacts.
    """
    fwd, adj = _operators(act, r, rho)
    n, m, _ = r.shape

    def apply_ata(x, alpha):
        y = fwd(x, alpha)                       # (M,T,D)
        e = jnp.sum(x, axis=1)                  # (N,)
        kty, sum_ry = adj(y)
        gx = kty + e[:, None]                   # K^T rho y + E^T e
        ga = -sum_ry                            # alpha rows of A^T
        return gx, ga

    def body(_, carry):
        x, alpha, _ = carry
        gx, ga = apply_ata(x, alpha)
        nrm = jnp.sqrt(jnp.sum(gx * gx) + jnp.sum(ga * ga)) + 1e-30
        return gx / nrm, ga / nrm, nrm

    x0 = jnp.ones((n, m), jnp.float32) / jnp.sqrt(jnp.float32(n * m))
    a0 = jnp.ones((m,), jnp.float32) / jnp.sqrt(jnp.float32(m))
    _, _, lam = jax.lax.fori_loop(0, n_iter, body, (x0, a0, jnp.float32(1)))
    # lam approximates ||A^T A||_2 = ||A||^2.
    return (jnp.sqrt(lam),)


def make_pdhg(n_iter: int):
    """Chunked-solver entry point with a static iteration count."""
    return functools.partial(pdhg_chunk, n_iter=n_iter)
