#!/usr/bin/env python3
"""tlrs-lint, Python mirror — determinism & safety analyzer for the Rust tree.

Line-for-line mirror of `rust/src/util/lint/` (lexer.rs + rules.rs) so the
gate runs even in containers without a Rust toolchain.  The two
implementations share the fixture corpus under `rust/tests/lint_fixtures/`
and must produce identical verdicts (pinned by
`python/tests/test_lint_mirror.py` and `rust/tests/lint_rules.rs`).

Rules (see docs/INVARIANTS.md for the why):
  unordered-iter  R1  no HashMap/HashSet on result paths
  float-ord       R2  no partial_cmp / float-literal == anywhere
  raw-spawn       R3  no raw threading outside util/pool.rs
  wallclock       R4  no Instant::now/SystemTime in the solver core
  panic-path      R5  no unwrap/expect/slice-index on the service path
  unsafe-audit    R6  every `unsafe` carries an adjacent SAFETY comment

Suppression: `// lint:allow(rule): reason` trailing the offending line or
in the contiguous comment block directly above it.  Allows are counted and
reported; a stale or malformed allow is itself a violation.
"""

import os
import sys

RULES = (
    "unordered-iter",
    "float-ord",
    "raw-spawn",
    "wallclock",
    "panic-path",
    "unsafe-audit",
)

# ---------------------------------------------------------------------------
# lexer — mirrors rust/src/util/lint/lexer.rs token for token
# ---------------------------------------------------------------------------

# kinds: ident num fnum str char life op comment
OPS3 = ("<<=", ">>=", "..=", "...")
OPS2 = (
    "::", "==", "!=", "<=", ">=", "&&", "||", "->", "=>", "..",
    "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
)


def _is_ident_start(c):
    return c.isalpha() or c == "_"


def _is_ident_cont(c):
    return c.isalnum() or c == "_"


def lex(src):
    """Tokenize Rust source into (kind, text, line) triples.

    Comments are kept as tokens (the rules need them); strings, chars and
    lifetimes are consumed precisely so braces/quotes inside them can
    never confuse the rule passes.
    """
    toks = []
    i, line, n = 0, 1, len(src)
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "/":
            j = i
            while j < n and src[j] != "\n":
                j += 1
            toks.append(("comment", src[i:j], line))
            i = j
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "*":
            start, depth, j = line, 1, i + 2
            while j < n and depth > 0:
                if src[j] == "/" and j + 1 < n and src[j + 1] == "*":
                    depth += 1
                    j += 2
                elif src[j] == "*" and j + 1 < n and src[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    if src[j] == "\n":
                        line += 1
                    j += 1
            toks.append(("comment", src[i:j], start))
            i = j
            continue
        # raw / byte string prefixes and raw identifiers
        if c == "r" or c == "b":
            j = i + 1
            if c == "b" and j < n and src[j] == "r":
                j += 1
            hashes = 0
            while j < n and src[j] == "#":
                hashes += 1
                j += 1
            raw_form = j > i + 1 or c == "r"  # r".., r#"..,  br".., b# is not raw
            if j < n and src[j] == '"' and raw_form:
                # raw (byte) string r"..", r#".."#, br".."  — no escapes
                j += 1
                close = '"' + "#" * hashes
                start = line
                while j < n and src[j:j + len(close)] != close:
                    if src[j] == "\n":
                        line += 1
                    j += 1
                j += len(close)
                toks.append(("str", src[i:j], start))
                i = j
                continue
            if c == "r" and hashes == 1 and j < n and _is_ident_start(src[j]):
                # raw identifier r#type
                k = j
                while k < n and _is_ident_cont(src[k]):
                    k += 1
                toks.append(("ident", src[j:k], line))
                i = k
                continue
            if c == "b" and i + 1 < n and src[i + 1] == '"':
                i2, line2 = _lex_quoted(src, i + 1, line)
                toks.append(("str", src[i:i2], line))
                i, line = i2, line2
                continue
            if c == "b" and i + 1 < n and src[i + 1] == "'":
                i2 = _lex_char(src, i + 1)
                toks.append(("char", src[i:i2], line))
                i = i2
                continue
            # plain identifier starting with r/b
        if _is_ident_start(c):
            j = i
            while j < n and _is_ident_cont(src[j]):
                j += 1
            toks.append(("ident", src[i:j], line))
            i = j
            continue
        if c.isdigit():
            i2, is_float = _lex_number(src, i)
            toks.append(("fnum" if is_float else "num", src[i:i2], line))
            i = i2
            continue
        if c == '"':
            i2, line2 = _lex_quoted(src, i, line)
            toks.append(("str", src[i:i2], line))
            i, line = i2, line2
            continue
        if c == "'":
            if i + 1 < n and src[i + 1] == "\\":
                i2 = _lex_char(src, i)
                toks.append(("char", src[i:i2], line))
                i = i2
                continue
            if i + 2 < n and _is_ident_start(src[i + 1]) and src[i + 2] != "'":
                # lifetime 'a / 'static
                j = i + 1
                while j < n and _is_ident_cont(src[j]):
                    j += 1
                toks.append(("life", src[i:j], line))
                i = j
                continue
            i2 = _lex_char(src, i)
            toks.append(("char", src[i:i2], line))
            i = i2
            continue
        if src[i:i + 3] in OPS3:
            toks.append(("op", src[i:i + 3], line))
            i += 3
            continue
        if src[i:i + 2] in OPS2:
            toks.append(("op", src[i:i + 2], line))
            i += 2
            continue
        toks.append(("op", c, line))
        i += 1
    return toks


def _lex_quoted(src, i, line):
    """Consume a normal "..." string starting at the quote; returns (end, line)."""
    n = len(src)
    j = i + 1
    while j < n:
        if src[j] == "\\":
            # an escaped newline (line continuation) still ends a line
            if j + 1 < n and src[j + 1] == "\n":
                line += 1
            j += 2
            continue
        if src[j] == "\n":
            line += 1
        if src[j] == '"':
            return j + 1, line
        j += 1
    return j, line


def _lex_char(src, i):
    """Consume a 'x' / '\\n' char literal starting at the quote; returns end."""
    n = len(src)
    j = i + 1
    while j < n:
        if src[j] == "\\":
            j += 2
            continue
        if src[j] == "'":
            return j + 1
        j += 1
    return j


def _lex_number(src, i):
    """Consume a numeric literal; returns (end, is_float)."""
    n = len(src)
    j = i
    if src[j] == "0" and j + 1 < n and src[j + 1] in "xob":
        j += 2
        while j < n and (src[j].isalnum() or src[j] == "_"):
            j += 1
        return j, False
    is_float = False
    while j < n and (src[j].isdigit() or src[j] == "_"):
        j += 1
    if j < n and src[j] == ".":
        nxt = src[j + 1] if j + 1 < n else ""
        if nxt.isdigit():
            is_float = True
            j += 1
            while j < n and (src[j].isdigit() or src[j] == "_"):
                j += 1
        elif nxt != "." and not _is_ident_start(nxt):
            # trailing-dot float like `1.`
            is_float = True
            j += 1
    if j < n and src[j] in "eE":
        k = j + 1
        if k < n and src[k] in "+-":
            k += 1
        if k < n and src[k].isdigit():
            is_float = True
            j = k
            while j < n and (src[j].isdigit() or src[j] == "_"):
                j += 1
    # type suffix (1usize, 2.5f64, 1f32)
    if j < n and _is_ident_start(src[j]):
        if src[j] == "f":
            is_float = True
        while j < n and _is_ident_cont(src[j]):
            j += 1
    return j, is_float


# ---------------------------------------------------------------------------
# rule engine — mirrors rust/src/util/lint/rules.rs
# ---------------------------------------------------------------------------

RUST_KEYWORDS = frozenset((
    "let", "mut", "ref", "in", "as", "return", "break", "continue", "move",
    "if", "else", "match", "for", "while", "loop", "where", "dyn", "box",
    "yield", "const", "static", "fn", "impl", "pub", "use", "mod", "enum",
    "struct", "trait", "type",
))

UNWRAP_LIKE = ("unwrap", "expect")
SPAWN_LIKE = ("spawn", "scope", "Builder")

R1_PREFIXES = ("algo/", "lp/", "model/", "io/", "sim/", "runtime/", "harness/")
R1_FILES = (
    "util/wire.rs", "util/json.rs",
    "coordinator/service.rs", "coordinator/session.rs",
)
R4_EXEMPT_FILES = (
    "coordinator/metrics.rs", "coordinator/runtime.rs",
    "coordinator/session.rs", "coordinator/planner.rs",
    "util/bench.rs", "main.rs",
)
R4_EXEMPT_PREFIXES = ("harness/", "bin/")
R5_FILES = ("coordinator/service.rs", "util/wire.rs")
R5_INDEX_FILES = ("coordinator/service.rs",)
R3_EXEMPT_FILES = ("util/pool.rs",)


def r1_applies(path):
    return path.startswith(R1_PREFIXES) or path in R1_FILES


def r3_applies(path):
    return path not in R3_EXEMPT_FILES


def r4_applies(path):
    return path not in R4_EXEMPT_FILES and not path.startswith(R4_EXEMPT_PREFIXES)


def r5_applies(path):
    return path in R5_FILES


def r5_index_applies(path):
    return path in R5_INDEX_FILES


def clean_comment(text):
    """Strip comment sigils so only the prose is stored in the inventory."""
    t = text.strip()
    if t.startswith("/*"):
        t = t[2:]
        if t.endswith("*/"):
            t = t[:-2]
    while t.startswith("/"):
        t = t[1:]
    if t.startswith("!"):
        t = t[1:]
    return t.strip()


def parse_allow(text):
    """Extract a lint:allow annotation from one comment.

    Returns (rule, reason) | None (no annotation) | ("", detail) when the
    annotation is present but malformed.
    """
    at = text.find("lint:allow(")
    if at < 0:
        return None
    rest = text[at + len("lint:allow("):]
    close = rest.find(")")
    if close < 0:
        return ("", "unclosed lint:allow annotation")
    rule = rest[:close].strip()
    tail = rest[close + 1:]
    if not tail.startswith(":"):
        return ("", "lint:allow needs `): reason`")
    reason = tail[1:].strip()
    if rule not in RULES:
        return ("", "unknown rule `%s` in lint:allow" % rule)
    if not reason:
        return ("", "empty reason in lint:allow(%s)" % rule)
    return (rule, reason)


class FileScan:
    """All per-file scanning state; `scan_source` drives it."""

    def __init__(self, path, src):
        self.path = path
        self.toks = lex(src)
        self.ct = [t for t in self.toks if t[0] != "comment"]
        self.skips = test_ranges(self.ct)
        self.skip_lines = set()
        for lo, hi in self.skips:
            self.skip_lines.update(
                range(self.ct[lo][2], self.ct[hi][2] + 1))
        self.has_code = set(t[2] for t in self.ct)
        self.comments = {}
        for t in self.toks:
            if t[0] == "comment":
                self.comments.setdefault(t[2], []).append(t[1])
        # allows: list of [line, rule, reason, used]
        self.allows = []
        self.bad_allows = []
        for ln in sorted(self.comments):
            for text in self.comments[ln]:
                got = parse_allow(text)
                if got is None:
                    continue
                rule, detail = got
                if rule == "":
                    self.bad_allows.append((ln, detail))
                else:
                    self.allows.append([ln, rule, detail, 0])

    def in_skip(self, ci):
        return any(lo <= ci <= hi for lo, hi in self.skips)

    def attached_lines(self, line):
        """The comment lines an annotation on `line` may live on: the line
        itself plus the contiguous run of comment-only lines above it."""
        out = [line]
        ln = line - 1
        while ln > 0 and ln in self.comments and ln not in self.has_code:
            out.append(ln)
            ln -= 1
        return out

    def find_allow(self, line, rule):
        for ln in self.attached_lines(line):
            for a in self.allows:
                if a[0] == ln and a[1] == rule:
                    return a
        return None

    def find_safety(self, line):
        for ln in self.attached_lines(line):
            for text in self.comments.get(ln, ()):
                if "safety" in text.lower():
                    return clean_comment(text)
        return None


def test_ranges(ct):
    """Token-index ranges (inclusive) of `#[cfg(test)]` / `#[test]` items."""
    ranges = []
    i, n = 0, len(ct)
    while i < n:
        if ct[i][1] == "#" and i + 1 < n and ct[i + 1][1] == "[":
            j, depth, idents = i + 2, 1, []
            while j < n and depth > 0:
                tx = ct[j][1]
                if tx == "[":
                    depth += 1
                elif tx == "]":
                    depth -= 1
                elif ct[j][0] == "ident":
                    idents.append(tx)
                j += 1
            gated = ("test" in idents and "not" not in idents
                     and (len(idents) == 1 or idents[0] == "cfg"))
            if gated:
                k = j
                while k < n and ct[k][1] not in ("{", ";"):
                    k += 1
                if k < n and ct[k][1] == "{":
                    d, k = 1, k + 1
                    while k < n and d > 0:
                        if ct[k][1] == "{":
                            d += 1
                        elif ct[k][1] == "}":
                            d -= 1
                        k += 1
                    ranges.append((i, k - 1))
            i = j
        else:
            i += 1
    return ranges


def scan_source(path, src):
    """Lint one file.  Returns (findings, allows_used, unsafe_blocks) where
    findings are (line, rule, msg) triples and unsafe_blocks are
    (line, safety|None, allow_reason|None) triples."""
    fs = FileScan(path, src)
    ct = fs.ct
    n = len(ct)
    raw = []  # (line, rule, msg)

    def tk(i):
        return ct[i][1] if 0 <= i < n else ""

    def kd(i):
        return ct[i][0] if 0 <= i < n else ""

    unsafe_blocks = []
    for i in range(n):
        if fs.in_skip(i):
            continue
        kind, text, line = ct[i]
        if kind == "ident":
            if text in ("HashMap", "HashSet") and r1_applies(path):
                raw.append((line, "unordered-iter",
                            "`%s` on a result path: iteration order is "
                            "nondeterministic — use BTreeMap/BTreeSet or "
                            "drain through a sort" % text))
            if text == "partial_cmp":
                raw.append((line, "float-ord",
                            "`partial_cmp` on floats: use `f64::total_cmp` "
                            "for a total, NaN-safe order"))
            if (text == "thread" and tk(i + 1) == "::"
                    and tk(i + 2) in SPAWN_LIKE and r3_applies(path)):
                raw.append((line, "raw-spawn",
                            "`thread::%s` outside util/pool.rs: route "
                            "threading through the pool primitives" % tk(i + 2)))
            if (text == "Instant" and tk(i + 1) == "::" and tk(i + 2) == "now"
                    and r4_applies(path)):
                raw.append((line, "wallclock",
                            "`Instant::now` in the solver core: wall-clock "
                            "reads belong to the coordinator/harness layers"))
            if text == "SystemTime" and r4_applies(path):
                raw.append((line, "wallclock",
                            "`SystemTime` in the solver core: wall-clock "
                            "reads belong to the coordinator/harness layers"))
            if (text in UNWRAP_LIKE and tk(i - 1) == "." and tk(i + 1) == "("
                    and r5_applies(path)):
                raw.append((line, "panic-path",
                            "`.%s()` on the service request path: return a "
                            "typed error instead" % text))
            if text == "unsafe":
                safety = fs.find_safety(line)
                allow = fs.find_allow(line, "unsafe-audit")
                if allow is not None:
                    allow[3] += 1
                unsafe_blocks.append(
                    (line, safety, allow[2] if allow else None))
                if safety is None:
                    raw.append((line, "unsafe-audit",
                                "`unsafe` without an adjacent "
                                "`// SAFETY:` comment"))
        elif kind == "op":
            if text in ("==", "!=") and (kd(i - 1) == "fnum" or kd(i + 1) == "fnum"):
                raw.append((line, "float-ord",
                            "float literal compared with `==`/`!=`: exact "
                            "float equality needs a justifying annotation"))
            if (text == "[" and r5_index_applies(path)
                    and ((kd(i - 1) == "ident" and tk(i - 1) not in RUST_KEYWORDS)
                         or tk(i - 1) in (")", "]"))):
                raw.append((line, "panic-path",
                            "slice index on the service request path: use "
                            "`get(..)` and return a typed error"))

    findings = []
    for line, rule, msg in raw:
        allow = fs.find_allow(line, rule)
        if allow is not None:
            allow[3] += 1
            continue
        findings.append((line, rule, msg))
    # unsafe-audit allows were consumed during the unsafe pass: drop the
    # findings they suppressed (find_allow above already re-matched them,
    # so nothing extra to do) — but a SAFETY-less unsafe with an allow
    # must not survive as a finding:
    findings = [f for f in findings
                if not (f[1] == "unsafe-audit" and fs.find_allow(f[0], "unsafe-audit"))]

    for ln, detail in fs.bad_allows:
        if ln not in fs.skip_lines:
            findings.append((ln, "bad-allow", detail))
    for a in fs.allows:
        if a[3] == 0 and a[0] not in fs.skip_lines:
            findings.append((a[0], "stale-allow",
                            "allow for `%s` suppresses nothing — remove it" % a[1]))
    findings.sort(key=lambda f: (f[0], f[1], f[2]))
    used = [(a[0], a[1], a[2]) for a in fs.allows if a[3] > 0]
    return findings, used, unsafe_blocks


# ---------------------------------------------------------------------------
# tree scan + reporting
# ---------------------------------------------------------------------------

def walk_rs(root):
    out = []
    for base, dirs, files in os.walk(root):
        dirs.sort()
        for f in sorted(files):
            if f.endswith(".rs"):
                full = os.path.join(base, f)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                out.append(rel)
    out.sort()
    return out


def json_escape(s):
    out = []
    for ch in s:
        if ch == '"':
            out.append('\\"')
        elif ch == "\\":
            out.append("\\\\")
        elif ch == "\n":
            out.append("\\n")
        elif ch == "\t":
            out.append("\\t")
        elif ch == "\r":
            out.append("\\r")
        elif ord(ch) < 0x20:
            out.append("\\u%04x" % ord(ch))
        else:
            out.append(ch)
    return "".join(out)


def unsafe_json(blocks):
    """blocks: list of (file, line, safety|None, allow|None), pre-sorted."""
    lines = ["{", '  "total": %d,' % len(blocks), '  "blocks": [']
    for i, (f, ln, safety, allow) in enumerate(blocks):
        s = "null" if safety is None else '"%s"' % json_escape(safety)
        a = "null" if allow is None else '"%s"' % json_escape(allow)
        comma = "," if i + 1 < len(blocks) else ""
        lines.append('    {"file": "%s", "line": %d, "safety": %s, '
                     '"allow": %s}%s' % (json_escape(f), ln, s, a, comma))
    lines.append("  ]")
    lines.append("}")
    return "\n".join(lines) + "\n"


def scan_tree(root):
    findings, allows, blocks = [], [], []
    files = walk_rs(root)
    for rel in files:
        with open(os.path.join(root, rel), "r", encoding="utf-8") as fh:
            src = fh.read()
        f, a, u = scan_source(rel, src)
        findings.extend((rel, ln, rule, msg) for ln, rule, msg in f)
        allows.extend((rel, ln, rule, reason) for ln, rule, reason in a)
        blocks.extend((rel, ln, safety, reason) for ln, safety, reason in u)
    findings.sort(key=lambda x: (x[0], x[1], x[2], x[3]))
    allows.sort(key=lambda x: (x[0], x[1], x[2]))
    blocks.sort(key=lambda x: (x[0], x[1]))
    return len(files), findings, allows, blocks


def main(argv):
    root = "rust/src"
    unsafe_out = None
    quiet = False
    i = 1
    while i < len(argv):
        if argv[i] == "--root" and i + 1 < len(argv):
            root = argv[i + 1]
            i += 2
        elif argv[i] == "--unsafe-out" and i + 1 < len(argv):
            unsafe_out = argv[i + 1]
            i += 2
        elif argv[i] == "--quiet":
            quiet = True
            i += 1
        else:
            sys.stderr.write("usage: lint.py [--root DIR] [--unsafe-out FILE]"
                             " [--quiet]\n")
            return 2
    n_files, findings, allows, blocks = scan_tree(root)
    for f, ln, rule, msg in findings:
        print("%s/%s:%d: [%s] %s" % (root, f, ln, rule, msg))
    if not quiet:
        for f, ln, rule, reason in allows:
            print("note: %s/%s:%d: lint:allow(%s): %s" % (root, f, ln, rule, reason))
    if unsafe_out is not None:
        with open(unsafe_out, "w", encoding="utf-8") as fh:
            fh.write(unsafe_json(blocks))
    print("tlrs-lint: scanned %d files: %d violation(s), %d allow(s) honored, "
          "%d unsafe block(s) inventoried"
          % (n_files, len(findings), len(allows), len(blocks)))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
