"""Differential mirror of the Rust wire layer's two JSON parsers.

The container this repo grows in has no rustc/cargo, so the Rust-side
differential fuzz (`rust/tests/prop_wire.rs`) cannot run here. This file
is the executable stand-in: faithful Python transliterations of

  * the recursive DOM parser in `rust/src/util/json.rs` (``DomParser``),
  * the non-recursive streaming pull parser in `rust/src/util/wire.rs`
    (``PullParser``),

fuzz-compared on random documents, byte-level mutations and a
handwritten edge corpus. The equivalence contract being checked is the
same one wire.rs documents: the pull parser accepts exactly the language
the DOM parser accepts and reports the *same error message at the same
byte position* on malformed input.

Two deliberate scope limits:

  * Values are compared as parsed Python objects (floats, strs, dicts,
    lists). Serialized float *strings* are never compared — Rust's
    ``Display`` and Python's ``repr`` legitimately differ (e.g. Rust
    prints ``0.000000001`` where Python prints ``1e-09``) even though
    both parse the same decimal to the same binary double.
  * Both mirrors operate on bytes with byte positions, exactly like the
    Rust originals; errors are ``(msg, pos)`` tuples.

Only the standard library is used.
"""

import json
import random

import pytest


class JsonErr(Exception):
    """Mirror of ``JsonError { msg, pos }``."""

    def __init__(self, msg, pos):
        super().__init__(f"json error at byte {pos}: {msg}")
        self.msg = msg
        self.pos = pos

    def tup(self):
        return (self.msg, self.pos)


WS = (0x20, 0x09, 0x0A, 0x0D)  # space, tab, \n, \r — both parsers' set
HEX_DIGITS = set(b"0123456789abcdefABCDEF")


def _from_str_radix_16(txt):
    """Rust ``u32::from_str_radix(txt, 16)`` for the 4-char escape slice.

    Python's ``int(s, 16)`` is looser (whitespace, underscores, ``0x``),
    so mirror Rust's grammar exactly: optional leading ``+``, then one
    or more hex digits, nothing else.
    """
    body = txt[1:] if txt.startswith("+") else txt
    if not body or any(ord(c) not in HEX_DIGITS for c in body):
        return None
    return int(body, 16)


def _unescape_u(b, i, err):
    """Shared ``\\u`` handling: ``i`` sits on the ``u`` byte.

    Returns ``(char, new_i)`` with ``new_i`` on the last hex digit (the
    caller's trailing ``i += 1`` then steps past it), or raises the
    Rust-identical "bad \\u escape" at ``i``.
    """
    if i + 4 >= len(b):
        raise err("bad \\u escape")
    try:
        hx = b[i + 1 : i + 5].decode("utf-8")
    except UnicodeDecodeError:
        raise err("bad \\u escape") from None
    code = _from_str_radix_16(hx)
    if code is None:
        raise err("bad \\u escape")
    # char::from_u32(code).unwrap_or(U+FFFD): 4 hex digits cap the code
    # at 0xFFFF, so the only invalid scalars are the surrogates
    c = "�" if 0xD800 <= code <= 0xDFFF else chr(code)
    return c, i + 4


def _scan_number(b, i):
    """Both parsers' identical number scanner; returns the end index."""
    if i < len(b) and b[i] == ord("-"):
        i += 1
    while i < len(b) and ord("0") <= b[i] <= ord("9"):
        i += 1
    if i < len(b) and b[i] == ord("."):
        i += 1
        while i < len(b) and ord("0") <= b[i] <= ord("9"):
            i += 1
    if i < len(b) and b[i] in (ord("e"), ord("E")):
        i += 1
        if i < len(b) and b[i] in (ord("+"), ord("-")):
            i += 1
        while i < len(b) and ord("0") <= b[i] <= ord("9"):
            i += 1
    return i


def _parse_f64(txt):
    """Rust ``txt.parse::<f64>()`` on a scanner-shaped token.

    Over the scanner's alphabet (``-0..9.eE+``) Rust's and Python's
    accepted grammars coincide (``1.``, ``.5``, ``-.5`` parse; ``-``,
    ``1e``, ``.`` do not), both are correctly rounded, and both overflow
    to inf (``1e999``). Python extras like underscores or ``inf`` are
    unreachable from the scanner.
    """
    try:
        return float(txt)
    except ValueError:
        return None


# --------------------------------------------------------------------------
# DomParser — transliteration of rust/src/util/json.rs `Parser`
# --------------------------------------------------------------------------


class DomParser:
    """Recursive-descent mirror; re-validates the UTF-8 *suffix of the
    whole input* at every ordinary string character, like the Rust DOM
    (which gets a ``&str`` in production but whose byte-level semantics
    the pull parser must reproduce)."""

    def __init__(self, b):
        self.b = b
        self.i = 0

    def err(self, msg):
        return JsonErr(msg, self.i)

    def peek(self):
        return self.b[self.i] if self.i < len(self.b) else None

    def skip_ws(self):
        while self.peek() in WS:
            self.i += 1

    def expect(self, c):
        if self.peek() == c:
            self.i += 1
        else:
            raise self.err(f"expected '{chr(c)}'")

    def lit(self, s, v):
        if self.b[self.i : self.i + len(s)] == s:
            self.i += len(s)
            return v
        raise self.err("invalid literal")

    def value(self):
        c = self.peek()
        if c == ord("{"):
            return self.object()
        if c == ord("["):
            return self.array()
        if c == ord('"'):
            return self.string()
        if c == ord("t"):
            return self.lit(b"true", True)
        if c == ord("f"):
            return self.lit(b"false", False)
        if c == ord("n"):
            return self.lit(b"null", None)
        if c is not None and (c == ord("-") or ord("0") <= c <= ord("9")):
            return self.number()
        raise self.err("unexpected character")

    def object(self):
        self.expect(ord("{"))
        m = {}
        self.skip_ws()
        if self.peek() == ord("}"):
            self.i += 1
            return m
        while True:
            self.skip_ws()
            k = self.string()
            self.skip_ws()
            self.expect(ord(":"))
            self.skip_ws()
            m[k] = self.value()  # dict insert: last key wins, like BTreeMap
            self.skip_ws()
            c = self.peek()
            if c == ord(","):
                self.i += 1
            elif c == ord("}"):
                self.i += 1
                return m
            else:
                raise self.err("expected ',' or '}'")

    def array(self):
        self.expect(ord("["))
        v = []
        self.skip_ws()
        if self.peek() == ord("]"):
            self.i += 1
            return v
        while True:
            self.skip_ws()
            v.append(self.value())
            self.skip_ws()
            c = self.peek()
            if c == ord(","):
                self.i += 1
            elif c == ord("]"):
                self.i += 1
                return v
            else:
                raise self.err("expected ',' or ']'")

    ESCAPES = {
        ord('"'): '"',
        ord("\\"): "\\",
        ord("/"): "/",
        ord("n"): "\n",
        ord("t"): "\t",
        ord("r"): "\r",
        ord("b"): "",
        ord("f"): "",
    }

    def string(self):
        self.expect(ord('"'))
        out = []
        while True:
            c = self.peek()
            if c is None:
                raise self.err("unterminated string")
            if c == ord('"'):
                self.i += 1
                return "".join(out)
            if c == ord("\\"):
                self.i += 1
                e = self.peek()
                if e in self.ESCAPES:
                    out.append(self.ESCAPES[e])
                elif e == ord("u"):
                    ch, self.i = _unescape_u(self.b, self.i, self.err)
                    out.append(ch)
                else:
                    raise self.err("bad escape")
                self.i += 1
            else:
                # copy a full utf-8 scalar; the Rust DOM validates the
                # remainder of the whole input here, every time
                start = self.i
                try:
                    rest = self.b[start:].decode("utf-8")
                except UnicodeDecodeError:
                    raise self.err("invalid utf-8") from None
                ch = rest[0]
                out.append(ch)
                self.i += len(ch.encode("utf-8"))

    def number(self):
        start = self.i
        self.i = _scan_number(self.b, self.i)
        x = _parse_f64(self.b[start : self.i].decode("utf-8"))
        if x is None:
            raise self.err("invalid number")
        return x


def dom_parse(b):
    """Mirror of ``json::parse``: ws, value, ws, full consumption."""
    p = DomParser(b)
    p.skip_ws()
    v = p.value()
    p.skip_ws()
    if p.i != len(p.b):
        raise p.err("trailing characters")
    return v


# --------------------------------------------------------------------------
# PullParser — transliteration of rust/src/util/wire.rs `JsonPull`
# --------------------------------------------------------------------------

# states
START, OBJ_FIRST, OBJ_KEY, VALUE, ARR_FIRST, ARR_VALUE, AFTER_VALUE, DONE = range(8)
OBJ, ARR = "obj", "arr"


def _utf8_len(lead):
    if lead <= 0x7F:
        return 1
    if 0xC0 <= lead <= 0xDF:
        return 2
    if 0xE0 <= lead <= 0xEF:
        return 3
    return 4


class PullParser:
    """Non-recursive state-machine mirror; validates the UTF-8 suffix
    once, at the first ordinary string character it ever sees, then
    steps strings by ``utf8_len`` without re-decoding."""

    def __init__(self, b):
        self.b = b
        self.i = 0
        self.stack = []
        self.state = START
        self.valid_from = None

    def err(self, msg):
        return JsonErr(msg, self.i)

    def peek(self):
        return self.b[self.i] if self.i < len(self.b) else None

    def skip_ws(self):
        while self.peek() in WS:
            self.i += 1

    def expect(self, c):
        if self.peek() == c:
            self.i += 1
        else:
            raise self.err(f"expected '{chr(c)}'")

    def lit(self, s):
        if self.b[self.i : self.i + len(s)] == s:
            self.i += len(s)
        else:
            raise self.err("invalid literal")

    def close(self, frame):
        assert self.stack and self.stack[-1] == frame
        self.stack.pop()
        self.state = DONE if not self.stack else AFTER_VALUE
        return ("obj_end",) if frame == OBJ else ("arr_end",)

    def end_scalar(self):
        self.state = DONE if not self.stack else AFTER_VALUE

    def next(self):
        while True:
            st = self.state
            if st == START:
                self.skip_ws()
                return self.value_event()
            if st == VALUE:
                return self.value_event()
            if st == OBJ_FIRST:
                self.skip_ws()
                if self.peek() == ord("}"):
                    self.i += 1
                    return self.close(OBJ)
                return self.key_event()
            if st == OBJ_KEY:
                self.skip_ws()
                return self.key_event()
            if st == ARR_FIRST:
                self.skip_ws()
                if self.peek() == ord("]"):
                    self.i += 1
                    return self.close(ARR)
                return self.value_event()
            if st == ARR_VALUE:
                self.skip_ws()
                return self.value_event()
            if st == AFTER_VALUE:
                self.skip_ws()
                frame = self.stack[-1]
                c = self.peek()
                if frame == OBJ:
                    if c == ord(","):
                        self.i += 1
                        self.state = OBJ_KEY
                    elif c == ord("}"):
                        self.i += 1
                        return self.close(OBJ)
                    else:
                        raise self.err("expected ',' or '}'")
                else:
                    if c == ord(","):
                        self.i += 1
                        self.state = ARR_VALUE
                    elif c == ord("]"):
                        self.i += 1
                        return self.close(ARR)
                    else:
                        raise self.err("expected ',' or ']'")
            elif st == DONE:
                self.skip_ws()
                if self.i != len(self.b):
                    raise self.err("trailing characters")
                return None

    def value_event(self):
        c = self.peek()
        if c == ord("{"):
            self.i += 1
            self.stack.append(OBJ)
            self.state = OBJ_FIRST
            return ("obj_start",)
        if c == ord("["):
            self.i += 1
            self.stack.append(ARR)
            self.state = ARR_FIRST
            return ("arr_start",)
        if c == ord('"'):
            s = self.string()
            self.end_scalar()
            return ("str", s)
        if c == ord("t"):
            self.lit(b"true")
            self.end_scalar()
            return ("bool", True)
        if c == ord("f"):
            self.lit(b"false")
            self.end_scalar()
            return ("bool", False)
        if c == ord("n"):
            self.lit(b"null")
            self.end_scalar()
            return ("null",)
        if c is not None and (c == ord("-") or ord("0") <= c <= ord("9")):
            x = self.number()
            self.end_scalar()
            return ("num", x)
        raise self.err("unexpected character")

    def key_event(self):
        k = self.string()
        self.skip_ws()
        self.expect(ord(":"))
        self.skip_ws()
        self.state = VALUE
        return ("key", k)

    def ensure_valid_utf8(self):
        if self.valid_from is None:
            try:
                self.b[self.i :].decode("utf-8")
            except UnicodeDecodeError:
                raise self.err("invalid utf-8") from None
            self.valid_from = self.i

    def str_slice(self, a, b):
        return self.b[a:b].decode("utf-8")

    def string(self):
        self.expect(ord('"'))
        start = self.i
        owned = None  # set on the first escape, like the Cow switch
        while True:
            c = self.peek()
            if c is None:
                raise self.err("unterminated string")
            if c == ord('"'):
                s = owned if owned is not None else self.str_slice(start, self.i)
                self.i += 1
                return s
            if c == ord("\\"):
                s = owned if owned is not None else self.str_slice(start, self.i)
                self.i += 1
                e = self.peek()
                if e in DomParser.ESCAPES:
                    s += DomParser.ESCAPES[e]
                elif e == ord("u"):
                    ch, self.i = _unescape_u(self.b, self.i, self.err)
                    s += ch
                else:
                    raise self.err("bad escape")
                self.i += 1
                owned = s
            else:
                self.ensure_valid_utf8()
                n = _utf8_len(c)
                if owned is not None:
                    owned += self.str_slice(self.i, self.i + n)
                self.i += n

    def number(self):
        start = self.i
        self.i = _scan_number(self.b, self.i)
        x = _parse_f64(self.b[start : self.i].decode("utf-8"))
        if x is None:
            raise self.err("invalid number")
        return x

    def parse_value(self):
        """Mirror of the Holder-stack ``parse_value`` — non-recursive."""
        stack = []  # entries: ["arr", list] or ["obj", dict, pending_key]
        while True:
            ev = self.next()
            if ev is None:
                raise self.err("unexpected character")
            tag = ev[0]
            if tag == "obj_start":
                stack.append([OBJ, {}, None])
                continue
            if tag == "arr_start":
                stack.append([ARR, []])
                continue
            if tag == "key":
                stack[-1][2] = ev[1]
                continue
            if tag == "obj_end":
                completed = stack.pop()[1]
            elif tag == "arr_end":
                completed = stack.pop()[1]
            elif tag == "null":
                completed = None
            else:  # str / num / bool
                completed = ev[1]
            if not stack:
                return completed
            top = stack[-1]
            if top[0] == ARR:
                top[1].append(completed)
            else:
                top[1][top[2]] = completed  # last key wins
                top[2] = None


def pull_parse(b):
    """Mirror of ``wire::parse_dom``."""
    p = PullParser(b)
    v = p.parse_value()
    assert p.next() is None, "top-level value already completed"
    return v


# --------------------------------------------------------------------------
# differential harness
# --------------------------------------------------------------------------


def run(parse, b):
    try:
        return ("ok", parse(b))
    except JsonErr as e:
        return ("err", e.tup())


def assert_parsers_agree(b):
    dom = run(dom_parse, b)
    pull = run(pull_parse, b)
    assert dom == pull, f"dom={dom!r} pull={pull!r} on {b!r}"
    return dom


STRING_POOL = [
    "",
    "a",
    "key",
    "with space",
    "quote\"inside",
    "back\\slash",
    "line\nbreak\ttab\rcr",
    "ctl",
    "",
    "unicode éπ中",
    "astral \U0001f980",
    "� replacement",
    "/slashes/",
]

NUMBER_TOKENS = [
    "0",
    "-0",
    "7",
    "-13",
    "3.25",
    "-0.5",
    "1e3",
    "2.5E-4",
    "1e+15",
    "-1.25e2",
    "9007199254740993",  # 2^53 + 1: parses fine, as_usize territory
    "1152921504606846976",  # 2^60
    "1e999",  # overflows to inf in both Rust and Python
    "1e-999",  # underflows to 0.0 in both
    "0.1",
    "123456.789",
]


def gen_string_text(rng):
    """A JSON string *token*, mixing raw chars, named and \\u escapes."""
    base = rng.choice(STRING_POOL)
    out = ['"']
    for ch in base:
        mode = rng.randrange(4)
        if ch in '"\\' or ord(ch) < 0x20:
            # must escape; pick named vs \u where a named form exists
            named = {'"': '\\"', "\\": "\\\\", "\n": "\\n", "\t": "\\t",
                     "\r": "\\r", "": "\\b", "": "\\f"}
            if ch in named and mode != 0:
                out.append(named[ch])
            else:
                out.append(f"\\u{ord(ch):04x}")
        elif mode == 0 and ord(ch) <= 0xFFFF:
            out.append(f"\\u{ord(ch):04x}")
        elif mode == 1 and ch == "/":
            out.append("\\/")
        else:
            out.append(ch)
    if rng.randrange(8) == 0:
        out.append("\\ud800")  # lone surrogate -> U+FFFD in both parsers
    out.append('"')
    return "".join(out)


def gen_ws(rng):
    return "".join(rng.choice([" ", "\t", "\n", "\r"]) for _ in range(rng.randrange(3)))


def gen_text(rng, depth):
    """A syntactically valid JSON document as text, random whitespace."""
    kind = rng.randrange(8) if depth > 0 else rng.randrange(6)
    if kind == 0:
        return "null"
    if kind == 1:
        return rng.choice(["true", "false"])
    if kind in (2, 3):
        return rng.choice(NUMBER_TOKENS)
    if kind in (4, 5):
        return gen_string_text(rng)
    if kind == 6:
        items = [gen_text(rng, depth - 1) for _ in range(rng.randrange(4))]
        return "[" + ",".join(gen_ws(rng) + it + gen_ws(rng) for it in items) + "]"
    pairs = [
        gen_ws(rng) + gen_string_text(rng) + gen_ws(rng) + ":" + gen_ws(rng)
        + gen_text(rng, depth - 1) + gen_ws(rng)
        for _ in range(rng.randrange(4))
    ]
    return "{" + ",".join(pairs) + "}"


SPLICE = b'{}[],:"\\0123456789eE.-+tfnu \t\n\rx'


def mutate(rng, b):
    """One byte-level mutation: truncate, overwrite, or insert."""
    kind = rng.randrange(3)
    if kind == 0 and b:
        return b[: rng.randrange(len(b))]
    if kind == 1 and b:
        i = rng.randrange(len(b))
        c = rng.randrange(256) if rng.randrange(4) == 0 else rng.choice(SPLICE)
        return b[:i] + bytes([c]) + b[i + 1 :]
    i = rng.randrange(len(b) + 1)
    return b[:i] + bytes([rng.choice(SPLICE)]) + b[i:]


def normalize_ints(v):
    """json.loads yields ints where the mirrors yield floats."""
    if isinstance(v, bool):
        return v
    if isinstance(v, int):
        return float(v)
    if isinstance(v, list):
        return [normalize_ints(x) for x in v]
    if isinstance(v, dict):
        return {k: normalize_ints(x) for k, x in v.items()}
    return v


# --------------------------------------------------------------------------
# tests
# --------------------------------------------------------------------------


class TestMirrorsAgree:
    def test_random_documents(self):
        """Valid generated docs parse identically through both mirrors."""
        for seed in range(200):
            rng = random.Random(seed)
            text = gen_text(rng, 4)
            b = text.encode("utf-8")
            status, _ = assert_parsers_agree(b)
            assert status == "ok", f"generated doc must parse: {text!r}"
            # whitespace wrapping is invisible to both
            wrapped = (gen_ws(rng) + text + gen_ws(rng)).encode("utf-8")
            assert assert_parsers_agree(wrapped) == assert_parsers_agree(b)

    def test_random_mutations(self):
        """Byte-level damage produces identical (msg, pos) errors."""
        for seed in range(200):
            rng = random.Random(10_000 + seed)
            b = gen_text(rng, 4).encode("utf-8")
            for _ in range(12):
                assert_parsers_agree(mutate(rng, b))

    def test_compound_mutations(self):
        """Repeated damage (mutations of mutations) still agrees."""
        for seed in range(60):
            rng = random.Random(20_000 + seed)
            b = gen_text(rng, 3).encode("utf-8")
            for _ in range(8):
                b = mutate(rng, b)
                assert_parsers_agree(b)

    def test_handwritten_edge_corpus(self):
        """The prop_wire.rs edge corpus, plus byte-position traps."""
        cases = [
            b"", b"{", b"[", b"]", b"}", b"[1,]", b'{"a":1,}', b"12 34",
            b"'single'", b'{"a" 1}', b"[1 2]", b"tru", b"fals", b"nul",
            b"truex", b'"unterminated', b'"bad \\q"', b'"bad \\u00',
            b'"\\u12"', b'"\\u+fff"', b'"\\uzzzz"', b'"\\ud800"',
            b'"\\udfff"', b'"\\ue000"', b'"\\u0041"', b'"a\\', b'"\\',
            b"-", b"+1", b"1e", b"1e+", b"01", b"1.", b".5", b"-.",
            b"-.5", b"1.e5", b"1e999", b"1e-999", b"{}", b"[]",
            b'{"":null}', b"[[[]]]", b'{"a":{"b":[1,{"c":2}]}}',
            b'{"dup":1,"dup":2}', b"  [ 1 , { \"k\" : [ true ] } ]  ",
            b'["\\n\\t\\r\\b\\f\\/\\\\\\""]', b"[,]", b"{,}", b'{"a",}',
            b'{"a":}', b"[1,,2]", b"nullnull", b"truefalse", b"1 ",
            b" 1", b"\t\n", b'"\xc3\xa9"', b'"\xf0\x9f\xa6\x80"',
        ]
        for b in cases:
            assert_parsers_agree(b)

    def test_exact_error_tuples(self):
        """A handful of hardcoded (msg, pos) expectations guard against
        both mirrors drifting *together* away from the Rust semantics."""
        expected = {
            b"": ("unexpected character", 0),
            b"{": ("expected '\"'", 1),
            b"[1,]": ("unexpected character", 3),
            b"12 34": ("trailing characters", 3),
            b"tru": ("invalid literal", 0),
            b'"bad \\q"': ("bad escape", 6),
            b'"bad \\u00': ("bad \\u escape", 6),
            b"-": ("invalid number", 1),
            b"1e": ("invalid number", 2),
            b'{"a":1,}': ("expected '\"'", 7),
            b'{"a" 1}': ("expected ':'", 5),
            b"[1 2]": ("expected ',' or ']'", 3),
            b'"unterminated': ("unterminated string", 13),
        }
        for b, tup in expected.items():
            for parse in (dom_parse, pull_parse):
                with pytest.raises(JsonErr) as exc:
                    parse(b)
                assert exc.value.tup() == tup, f"{parse.__name__} on {b!r}"

    def test_invalid_utf8_bytes(self):
        """Raw invalid bytes: inside strings both fail with the DOM's
        whole-suffix "invalid utf-8" at the first ordinary string char
        (the key's first byte here, position 2); outside strings they
        are a plain syntax error."""
        bad = b'{"k":"a\xff"}'
        for parse in (dom_parse, pull_parse):
            with pytest.raises(JsonErr) as exc:
                parse(bad)
            assert exc.value.tup() == ("invalid utf-8", 2)
        assert_parsers_agree(bad)
        assert_parsers_agree(b"\xff\xfe")
        assert_parsers_agree(b'["ok", "\xc3"]')  # truncated 2-byte char
        assert_parsers_agree(b'"\xed\xa0\x80"')  # utf-8-encoded surrogate
        # escape-only string before the invalid byte: validation fires at
        # the first *ordinary* char, which sits after the escapes
        assert_parsers_agree(b'"\\n\\tz\xff"')

    def test_lone_surrogate_escape_becomes_replacement(self):
        """char::from_u32 on a surrogate is None -> U+FFFD in both."""
        for b in (b'"\\ud800"', b'"\\udbff"', b'"\\udfff"'):
            assert dom_parse(b) == "�"
            assert pull_parse(b) == "�"
        # non-surrogate BMP chars come through exact
        assert pull_parse(b'"\\u4e2d"') == "中"

    def test_deep_nesting(self):
        """Differential at the Rust test's depth; the pull mirror alone
        far past any recursion limit (it carries an explicit stack)."""
        doc = ("[" * 200 + "]" * 200).encode()
        assert assert_parsers_agree(doc)[0] == "ok"
        deep = ("[" * 3000 + "]" * 3000).encode()
        v = pull_parse(deep)
        for _ in range(2999):
            assert isinstance(v, list) and len(v) == 1
            v = v[0]
        assert v == []

    def test_against_stdlib_json(self):
        """Sanity anchor: on documents produced by json.dumps (no exotic
        escapes), the DOM mirror agrees with json.loads — the mirror is
        a real JSON parser, not just self-consistent with its twin."""
        for seed in range(60):
            rng = random.Random(30_000 + seed)
            value = normalize_ints(json.loads(
                "[" + ",".join(
                    rng.choice(['null', 'true', '-2.5', '7', '{"k":[1,2]}',
                                '"text"', '[]', '{"a":{"b":null}}'])
                    for _ in range(rng.randrange(1, 6))
                ) + "]"
            ))
            text = json.dumps(value).encode("utf-8")
            assert normalize_ints(dom_parse(text)) == value
            assert normalize_ints(pull_parse(text)) == value

    def test_number_token_values(self):
        """Every generator number token parses to the same float through
        both mirrors and Python's float (correct rounding on all sides),
        including the 1e999 -> inf overflow both parsers share."""
        for tok in NUMBER_TOKENS:
            b = tok.encode()
            assert dom_parse(b) == pull_parse(b) == float(tok), tok
        assert dom_parse(b"1e999") == float("inf")
        assert dom_parse(b"1e-999") == 0.0


class TestMaxSafeInt:
    """Mirror of json.rs `num_is_usize`: the as_usize gate shared by the
    DOM accessor and the typed streaming decoders."""

    MAX_SAFE_INT = 9007199254740992.0  # 2^53

    @staticmethod
    def num_is_usize(x):
        import math
        return x >= 0.0 and math.modf(x)[0] == 0.0 and x <= TestMaxSafeInt.MAX_SAFE_INT

    def test_boundary(self):
        ok = self.num_is_usize
        assert ok(0.0) and ok(7.0) and ok(self.MAX_SAFE_INT)
        assert not ok(-1.0)
        assert not ok(2.5)
        assert not ok(self.MAX_SAFE_INT * 2)
        assert not ok(float("inf"))
        assert not ok(float("nan"))  # nan.fract() is nan -> != 0

    def test_parsed_large_ids_are_rejected(self):
        """An id literal above 2^53 parses as a float fine but must fail
        the usize gate (it silently snapped to a neighboring integer)."""
        x = dom_parse(b"9007199254740993")  # 2^53 + 1 rounds to 2^53
        assert x == self.MAX_SAFE_INT
        assert self.num_is_usize(x)  # the *rounded* value is in range...
        y = dom_parse(b"18014398509481984")  # 2^54
        assert not self.num_is_usize(y)  # ...but past the cap it fails
