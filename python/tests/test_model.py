"""L2 model shape/lowering tests: residual semantics and AOT HLO emission."""

import numpy as np

import jax
import jax.numpy as jnp

from compile import model as M
from compile import aot


def tiny(rng, n=6, m=2, t=4, d=2):
    dem = rng.uniform(0.05, 0.3, (n, d)).astype(np.float32)
    cap = rng.uniform(0.5, 1.0, (m, d)).astype(np.float32)
    cost = rng.uniform(0.5, 2.0, m).astype(np.float32)
    act = (rng.random((t, n)) < 0.6).astype(np.float32)
    r = (dem[:, None, :] / cap[None, :, :]).astype(np.float32)
    rho = np.ones((m, t, d), np.float32)
    return act, r, rho, cost


class TestResiduals:
    def test_zero_state_residuals(self):
        """From the zero state: eq violated by 1, ineq/dual feasible."""
        rng = np.random.default_rng(0)
        act, r, rho, cost = tiny(rng)
        n, m, _ = r.shape
        tmask = np.ones(n, np.float32)
        res = np.asarray(M.residuals(
            act, r, rho, cost, tmask,
            np.zeros((n, m), np.float32), np.zeros(m, np.float32),
            np.zeros_like(rho), np.zeros(n, np.float32)))
        assert res.shape == (4,)
        np.testing.assert_allclose(res[0], 1.0)   # sum_B x - 1 = -1
        np.testing.assert_allclose(res[1], 0.0)   # K0 - 0 <= 0
        np.testing.assert_allclose(res[2], 0.0)   # duals feasible at 0

    def test_feasible_point_zero_primal_residual(self):
        """x uniform + alpha = max load -> primal residuals vanish."""
        rng = np.random.default_rng(1)
        act, r, rho, cost = tiny(rng)
        n, m, d = r.shape
        x = np.full((n, m), 1.0 / m, np.float32)
        kx = np.einsum("tu,ub,ubd->btd", act, x, r)
        alpha = kx.max(axis=(1, 2)).astype(np.float32)
        res = np.asarray(M.residuals(
            act, r, rho, cost, np.ones(n, np.float32), x, alpha,
            np.zeros_like(rho), np.zeros(n, np.float32)))
        assert res[0] < 1e-6 and res[1] < 1e-6

    def test_chunk_monotone_progress(self):
        """Max residual after 400 iters is below the 100-iter value."""
        rng = np.random.default_rng(2)
        act, r, rho, cost = tiny(rng, n=10, m=3, t=8, d=2)
        n, m, _ = r.shape
        tmask, bmask = np.ones(n, np.float32), np.ones(m, np.float32)
        nrm = float(M.power_iter(act, r, rho, n_iter=60)[0])
        tau = sigma = np.float32(0.9 / nrm)
        z = lambda *s: np.zeros(s, np.float32)
        step = jax.jit(M.make_pdhg(100))
        st = (act, r, rho, cost, tmask, bmask)
        x, al, y, w, *_, d1 = step(*st, z(n, m), z(m), z(m, act.shape[0], 2),
                                   z(n), tau, sigma)
        for _ in range(3):
            x, al, y, w, *_, d2 = step(*st, x, al, y, w, tau, sigma)
        assert float(np.max(np.asarray(d2)[:4])) < \
            float(np.max(np.asarray(d1)[:4])) + 1e-9


class TestAot:
    def test_hlo_text_emission(self):
        """A tiny bucket lowers to parseable HLO text for all 3 programs."""
        files = aot.lower_bucket("t0", 8, 2, 4, 2, 5)
        assert set(files) == {"pdhg_t0.hlo.txt", "power_t0.hlo.txt",
                              "penalty_t0.hlo.txt"}
        for name, text in files.items():
            assert text.startswith("HloModule"), name
            assert "ROOT" in text, name

    def test_bucket_table_sane(self):
        names = [b[0] for b in aot.BUCKETS]
        assert len(names) == len(set(names))
        for _, n, m, t, d, iters in aot.BUCKETS:
            assert n >= 1 and m >= 1 and t >= 1 and d >= 1 and iters >= 1
