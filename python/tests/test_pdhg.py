"""L2 PDHG solver correctness: against scipy.optimize.linprog ground truth.

Builds the dense mapping LP explicitly (the L2 solver never does) and
checks objective agreement, residual convergence, padding invariance and
the dual lower-bound property.
"""

import numpy as np
import pytest

import jax

from compile import model as M


def random_instance(rng, n, m, t, d):
    dem = rng.uniform(0.02, 0.3, (n, d)).astype(np.float32)
    cap = rng.uniform(0.5, 1.0, (m, d)).astype(np.float32)
    cost = rng.uniform(0.5, 3.0, m).astype(np.float32)
    s = rng.integers(0, t, n)
    e = np.minimum(t - 1, s + rng.integers(0, max(1, t // 2), n))
    act = np.zeros((t, n), np.float32)
    for u in range(n):
        act[s[u]:e[u] + 1, u] = 1.0
    r = (dem[:, None, :] / cap[None, :, :]).astype(np.float32)
    return dem, cap, cost, act, r


def scipy_opt(act, r, cost):
    from scipy.optimize import linprog
    t, n = act.shape
    _, m, d = r.shape
    nv = n * m + m
    c = np.zeros(nv)
    c[n * m:] = cost
    a_eq = np.zeros((n, nv))
    for u in range(n):
        a_eq[u, u * m:(u + 1) * m] = 1.0
    rows = []
    for b in range(m):
        for ts in range(t):
            if not act[ts].any():
                continue
            for dd in range(d):
                row = np.zeros(nv)
                row[np.arange(n) * m + b] = act[ts] * r[:, b, dd]
                row[n * m + b] = -1.0
                rows.append(row)
    res = linprog(c, A_ub=np.array(rows), b_ub=np.zeros(len(rows)),
                  A_eq=a_eq, b_eq=np.ones(n), bounds=[(0, None)] * nv,
                  method="highs")
    assert res.status == 0
    return res.fun


def solve_pdhg(act, r, cost, chunks=40, iters=200, rho=None):
    t, n = act.shape
    _, m, d = r.shape
    rho = np.ones((m, t, d), np.float32) if rho is None else rho
    tmask, bmask = np.ones(n, np.float32), np.ones(m, np.float32)
    nrm = float(M.power_iter(act, r, rho, n_iter=60)[0])
    tau = sigma = np.float32(0.9 / nrm)
    x = np.zeros((n, m), np.float32)
    al = np.zeros(m, np.float32)
    y = np.zeros((m, t, d), np.float32)
    w = np.zeros(n, np.float32)
    step = jax.jit(M.make_pdhg(iters))
    for _ in range(chunks):
        x, al, y, w, xa, aa, ya, wa, diag = step(
            act, r, rho, cost, tmask, bmask, x, al, y, w, tau, sigma)
        if float(np.max(np.asarray(diag)[:4])) < 1e-6:
            break
    return np.asarray(x), np.asarray(al), np.asarray(y), np.asarray(w), \
        np.asarray(diag)


class TestPdhgVsScipy:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_objective_matches(self, seed):
        rng = np.random.default_rng(seed)
        n, m, t, d = 12, 3, 8, 2
        dem, cap, cost, act, r = random_instance(rng, n, m, t, d)
        want = scipy_opt(act, r, cost)
        x, al, y, w, diag = solve_pdhg(act, r, cost)
        got = float(np.dot(cost, al))
        assert abs(got - want) <= 2e-4 * (1.0 + abs(want))

    def test_residuals_converge(self):
        rng = np.random.default_rng(3)
        dem, cap, cost, act, r = random_instance(rng, 16, 4, 8, 3)
        x, al, y, w, diag = solve_pdhg(act, r, cost)
        assert np.max(diag[:4]) < 1e-5

    def test_dual_is_lower_bound(self):
        """sum(w) at convergence lower-bounds the scipy optimum."""
        rng = np.random.default_rng(4)
        dem, cap, cost, act, r = random_instance(rng, 12, 3, 8, 2)
        want = scipy_opt(act, r, cost)
        x, al, y, w, diag = solve_pdhg(act, r, cost)
        assert np.sum(w) <= want + 1e-3 * (1 + abs(want))

    def test_row_scaling_invariant(self):
        """Ruiz-style row scaling must not change the optimum."""
        rng = np.random.default_rng(5)
        n, m, t, d = 12, 3, 8, 2
        dem, cap, cost, act, r = random_instance(rng, n, m, t, d)
        _, al0, *_ = solve_pdhg(act, r, cost)
        rho = rng.uniform(0.5, 2.0, (m, t, d)).astype(np.float32)
        _, al1, *_ = solve_pdhg(act, r, cost, rho=rho)
        o0, o1 = np.dot(cost, al0), np.dot(cost, al1)
        assert abs(o0 - o1) <= 5e-4 * (1 + abs(o0))


class TestPadding:
    def test_padding_invariance(self):
        """Zero-padding tasks/types/slots/dims must not change the optimum."""
        rng = np.random.default_rng(6)
        n, m, t, d = 10, 3, 8, 2
        dem, cap, cost, act, r = random_instance(rng, n, m, t, d)
        _, al0, *_ = solve_pdhg(act, r, cost)
        o0 = float(np.dot(cost, al0))

        np_, mp, tp, dp = 16, 5, 16, 3
        act_p = np.zeros((tp, np_), np.float32)
        act_p[:t, :n] = act
        r_p = np.zeros((np_, mp, dp), np.float32)
        r_p[:n, :m, :d] = r
        rho_p = np.zeros((mp, tp, dp), np.float32)
        rho_p[:m, :t, :d] = 1.0
        cost_p = np.zeros(mp, np.float32)
        cost_p[:m] = cost
        tmask = np.zeros(np_, np.float32)
        tmask[:n] = 1.0
        bmask = np.zeros(mp, np.float32)
        bmask[:m] = 1.0

        nrm = float(M.power_iter(act_p, r_p, rho_p, n_iter=60)[0])
        tau = sigma = np.float32(0.9 / nrm)
        x = np.zeros((np_, mp), np.float32)
        al = np.zeros(mp, np.float32)
        y = np.zeros((mp, tp, dp), np.float32)
        w = np.zeros(np_, np.float32)
        step = jax.jit(M.make_pdhg(200))
        for _ in range(40):
            x, al, y, w, xa, aa, ya, wa, diag = step(
                act_p, r_p, rho_p, cost_p, tmask, bmask, x, al, y, w,
                tau, sigma)
            if float(np.max(np.asarray(diag)[:4])) < 1e-6:
                break
        o1 = float(np.dot(cost_p, al))
        assert abs(o0 - o1) <= 5e-4 * (1 + abs(o0))
        # padded x-columns stay empty
        assert float(np.abs(np.asarray(x)[:, m:]).max()) == 0.0


class TestPowerIter:
    def test_matches_dense_norm(self):
        """power_iter vs numpy SVD of the explicitly-built operator."""
        rng = np.random.default_rng(7)
        n, m, t, d = 8, 2, 4, 2
        dem, cap, cost, act, r = random_instance(rng, n, m, t, d)
        rho = np.ones((m, t, d), np.float32)
        got = float(M.power_iter(act, r, rho, n_iter=200)[0])
        # dense operator: rows = m*t*d ineq + n eq, cols = n*m + m
        nv = n * m + m
        rows = []
        for b in range(m):
            for ts in range(t):
                for dd in range(d):
                    row = np.zeros(nv)
                    row[np.arange(n) * m + b] = act[ts] * r[:, b, dd]
                    row[n * m + b] = -1.0
                    rows.append(row)
        for u in range(n):
            row = np.zeros(nv)
            row[u * m:(u + 1) * m] = 1.0
            rows.append(row)
        want = np.linalg.svd(np.array(rows), compute_uv=False)[0]
        assert abs(got - want) <= 1e-2 * want
