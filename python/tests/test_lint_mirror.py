"""Mirror tests for tlrs-lint: the Python implementation must agree with
the Rust one fixture-for-fixture and byte-for-byte on the inventory.

The Rust side (``rust/tests/lint_rules.rs``) runs the same corpus under
``rust/tests/lint_fixtures/`` through ``util::lint``; this file runs it
through ``python/tools/lint.py``. Both parse the same two-line header:

    //! path: algo/example.rs
    //! expect: unordered-iter@4 float-ord@9     (or: clean)

so any divergence between the implementations shows up as one side
failing its fixture suite. The repo-clean and inventory tests below are
the toolchain-less stand-ins for the Rust gate in containers without
cargo.
"""

import importlib.util
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parents[2]
FIXTURES = REPO / "rust" / "tests" / "lint_fixtures"

_spec = importlib.util.spec_from_file_location(
    "tlrs_lint", REPO / "python" / "tools" / "lint.py")
lint = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(lint)


def parse_header(src, name):
    lines = src.splitlines()
    assert lines[0].startswith("//! path: "), f"{name}: missing path header"
    assert lines[1].startswith("//! expect: "), f"{name}: missing expect header"
    path = lines[0][len("//! path: "):].strip()
    spec = lines[1][len("//! expect: "):].strip()
    want = []
    if spec != "clean":
        for entry in spec.split():
            rule, _, line = entry.partition("@")
            want.append((int(line), rule))
    return path, sorted(want)


def fixture_files():
    files = sorted(FIXTURES.glob("*.rs"))
    assert len(files) >= 15, "fixture corpus shrank"
    return files


@pytest.mark.parametrize("file", fixture_files(), ids=lambda p: p.name)
def test_fixture_verdicts(file):
    src = file.read_text(encoding="utf-8")
    path, want = parse_header(src, file.name)
    findings, _used, _blocks = lint.scan_source(path, src)
    got = sorted((ln, rule) for ln, rule, _msg in findings)
    assert got == want, f"{file.name}: verdicts diverge from header"


def test_allow_fixtures_exercise_suppression():
    for name, min_allows in [
        ("r1_allow.rs", 3),
        ("r2_float_allow.rs", 1),
        ("r6_unsafe_allow.rs", 1),
    ]:
        src = (FIXTURES / name).read_text(encoding="utf-8")
        path, _ = parse_header(src, name)
        _findings, used, _blocks = lint.scan_source(path, src)
        assert len(used) >= min_allows, f"{name}: allows not honored"


def test_repo_sources_are_lint_clean():
    n_files, findings, _allows, _blocks = lint.scan_tree(str(REPO / "rust" / "src"))
    assert n_files > 50, "src tree went missing?"
    rendered = ["%s:%d: [%s] %s" % f for f in findings]
    assert not rendered, "the crate's own sources violate the lint:\n" + "\n".join(rendered)


def test_unsafe_inventory_is_complete_and_committed():
    _n, _findings, _allows, blocks = lint.scan_tree(str(REPO / "rust" / "src"))
    assert blocks, "the pool/pdhg unsafe blocks vanished?"
    for f, ln, safety, allow in blocks:
        assert safety is not None or allow is not None, (
            f"{f}:{ln}: unsafe block with neither SAFETY comment nor allow")
    committed = (REPO / "LINT_unsafe.json").read_text(encoding="utf-8")
    assert lint.unsafe_json(blocks) == committed, (
        "LINT_unsafe.json is stale — regenerate with scripts/lint.sh")


def test_malformed_allow_details():
    # the three malformation shapes produce the documented diagnostics
    cases = [
        ("// lint:allow(float-ord missing close\nlet x = 1;\n",
         "unclosed lint:allow annotation"),
        ("// lint:allow(float-ord) no colon\nlet x = 1;\n",
         "lint:allow needs `): reason`"),
        ("// lint:allow(bogus): reason\nlet x = 1;\n",
         "unknown rule `bogus` in lint:allow"),
        ("// lint:allow(float-ord):\nlet x = 1;\n",
         "empty reason in lint:allow(float-ord)"),
    ]
    for src, detail in cases:
        findings, _, _ = lint.scan_source("algo/example.rs", src)
        assert [(f[1], f[2]) for f in findings] == [("bad-allow", detail)], src
