"""Fuzz mirror for PR 9's deterministic-reduction contract (lp/pdhg.rs).

The parallel PDHG engine claims bit-identical results at every thread
count because (a) blocks write disjoint outputs and only interchange
*independent* iterations, (b) every scalar f64 sum keeps its serial
per-element order (per-chunk/per-block local accumulators combined in
fixed index order), and (c) max reductions split into 0.0-baseline
chunk partials folded in chunk order, exact because f64::max is
associative (including its NaN-dropping semantics).

Python floats are IEEE-754 binary64 like Rust f64, so the claims are
checkable here bit-for-bit: each test mirrors one Rust kernel's serial
order and its chunked/blocked decomposition (with blocks executed in a
*shuffled* order, mimicking scheduling nondeterminism) and asserts the
bit patterns agree. Run: python3 python/tests/test_parallel_reductions.py
"""

import math
import random
import struct

TASK_CHUNK = 1024  # mirrors pdhg::TASK_CHUNK


def bits(x):
    return struct.pack("<d", x)


def f64_max(a, b):
    # Rust f64::max: NaN-dropping (if one arg is NaN, the other wins).
    if math.isnan(a):
        return b
    if math.isnan(b):
        return a
    return max(a, b)


def chunks(n, width=TASK_CHUNK):
    return [(s, min(s + width, n)) for s in range(0, n, width)]


def test_max_by_chunks(trials=400):
    """max_by_chunks: 0.0-baseline chunk partials folded in chunk order
    == the serial 0.0-init fold, bitwise, incl. NaN/inf elements."""
    rng = random.Random(11)
    for t in range(trials):
        n = rng.randrange(1, 5000)
        vals = []
        for _ in range(n):
            r = rng.random()
            if r < 0.02:
                vals.append(float("nan"))
            elif r < 0.04:
                vals.append(float("inf"))
            else:
                # residual-like: non-negative magnitudes across scales
                vals.append(abs(rng.gauss(0, 1)) * 10 ** rng.randrange(-12, 12))
        serial = 0.0
        for v in vals:
            serial = f64_max(serial, v)
        block_ix = list(range(len(chunks(n))))
        rng.shuffle(block_ix)  # blocks run in any order...
        partials = {}
        for b in block_ix:
            s, e = chunks(n)[b]
            acc = 0.0
            for v in vals[s:e]:
                acc = f64_max(acc, v)
            partials[b] = acc
        par = 0.0
        for b in range(len(chunks(n))):  # ...but combine in chunk order
            par = f64_max(par, partials[b])
        assert bits(serial) == bits(par), f"trial {t}: {serial} vs {par}"


def test_chunked_row_accumulation(trials=200):
    """The primal step's per-task row sum: serial `rows[i] += x[b*n+i]`
    b-ascending == per-chunk local accumulator, any chunk order."""
    rng = random.Random(23)
    for t in range(trials):
        n = rng.randrange(1, 3000)
        m = rng.randrange(1, 7)
        x = [rng.gauss(0, 1) * 10 ** rng.randrange(-8, 8) for _ in range(m * n)]
        serial = [0.0] * n
        for i in range(n):
            row = 0.0
            for b in range(m):
                row += x[b * n + i]
            serial[i] = row
        par = [0.0] * n
        block_ix = list(range(len(chunks(n))))
        rng.shuffle(block_ix)
        for c in block_ix:
            s, e = chunks(n)[c]
            for i in range(s, e):
                acc = 0.0
                for b in range(m):  # same b-ascending per-element order
                    acc += x[b * n + i]
                par[i] = acc
        for i in range(n):
            assert bits(serial[i]) == bits(par[i]), f"trial {t} row {i}"


def test_blocked_prefix_lanes(trials=200):
    """forward/adjoint (b,d)-blocks: diff+prefix lanes write disjoint
    outputs, so executing blocks in any order is bitwise identical."""
    rng = random.Random(37)
    for t in range(trials):
        m = rng.randrange(1, 5)
        dims = rng.randrange(1, 4)
        T = rng.randrange(2, 40)
        segs = []
        for _ in range(rng.randrange(1, 200)):
            s = rng.randrange(0, T)
            e = rng.randrange(s, T)
            segs.append((s, e, rng.random() * 10 ** rng.randrange(-6, 6)))

        def run(order):
            out = [0.0] * (m * dims * (T + 1))
            for k in order:
                b, d = divmod(k, dims)
                lane = k * (T + 1)
                # diff scatter then prefix, exactly like forward_tm
                for (s, e, r) in segs:
                    out[lane + s] += r * (b + 1) * (d + 1)
                    out[lane + e + 1] -= r * (b + 1) * (d + 1)
                for ts in range(1, T + 1):
                    out[lane + ts] += out[lane + ts - 1]
            return out

        serial = run(list(range(m * dims)))
        shuffled = list(range(m * dims))
        rng.shuffle(shuffled)
        par = run(shuffled)
        for i, (a, b2) in enumerate(zip(serial, par)):
            assert bits(a) == bits(b2), f"trial {t} lane elem {i}"


def test_serial_ga_combine(trials=200):
    """adjoint's ga[b] = sum_d ga_part[b*dims+d], combined serially in
    d-ascending order after the parallel phase == the pre-PR in-place
    `ga[b] += prefix[T]` accumulation in d-ascending order."""
    rng = random.Random(53)
    for t in range(trials):
        m = rng.randrange(1, 8)
        dims = rng.randrange(1, 6)
        part = [rng.gauss(0, 1) * 10 ** rng.randrange(-10, 10)
                for _ in range(m * dims)]
        for b in range(m):
            old = 0.0
            for d in range(dims):  # pre-PR order
                old += part[b * dims + d]
            new = 0.0
            for d in range(dims):  # fixed-order combine of block partials
                new += part[b * dims + d]
            assert bits(old) == bits(new), f"trial {t} type {b}"


if __name__ == "__main__":
    test_max_by_chunks()
    test_chunked_row_accumulation()
    test_blocked_prefix_lanes()
    test_serial_ga_combine()
    print("parallel-reduction mirror: all fuzz checks passed")
