"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes; every property is checked with
assert_allclose against the reference implementation.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.fused_matmul import (
    fused_scale_matmul, k_forward, k_adjoint, _pick_block_rows)
from compile.kernels.penalty import penalty_scores

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape, dtype=np.float32):
    return rng.uniform(-1.0, 1.0, shape).astype(dtype)


@st.composite
def matmul_shapes(draw):
    t = draw(st.sampled_from([1, 2, 4, 8, 16, 32, 64, 96, 128, 256]))
    n = draw(st.integers(1, 48))
    k = draw(st.integers(1, 40))
    return t, n, k


class TestFusedScaleMatmul:
    @settings(**SETTINGS)
    @given(shapes=matmul_shapes(), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shapes, seed):
        t, n, k = shapes
        rng = np.random.default_rng(seed)
        a, x, s = _rand(rng, t, n), _rand(rng, n, k), _rand(rng, n, k)
        got = fused_scale_matmul(a, x, s)
        want = ref.fused_scale_matmul_ref(a, x, s)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_explicit_block_rows(self):
        rng = np.random.default_rng(0)
        a, x, s = _rand(rng, 64, 8, dtype=np.float32), _rand(rng, 8, 4), _rand(rng, 8, 4)
        for br in (1, 2, 4, 8, 16, 32, 64):
            got = fused_scale_matmul(a, x, s, block_rows=br)
            np.testing.assert_allclose(got, a @ (x * s), rtol=2e-5, atol=2e-5)

    def test_bad_block_rows_rejected(self):
        rng = np.random.default_rng(0)
        a, x, s = _rand(rng, 6, 4), _rand(rng, 4, 3), _rand(rng, 4, 3)
        with pytest.raises(AssertionError):
            fused_scale_matmul(a, x, s, block_rows=4)

    def test_shape_mismatch_rejected(self):
        rng = np.random.default_rng(0)
        with pytest.raises(AssertionError):
            fused_scale_matmul(_rand(rng, 4, 5), _rand(rng, 6, 3), _rand(rng, 6, 3))

    def test_zero_operand(self):
        rng = np.random.default_rng(1)
        a = np.zeros((32, 8), np.float32)
        x, s = _rand(rng, 8, 4), _rand(rng, 8, 4)
        np.testing.assert_array_equal(np.asarray(fused_scale_matmul(a, x, s)), 0.0)

    def test_pick_block_rows(self):
        assert _pick_block_rows(256) == 128
        assert _pick_block_rows(96) == 32
        assert _pick_block_rows(7) == 1
        for t in (1, 2, 3, 12, 24, 100, 1024):
            assert t % _pick_block_rows(t) == 0


@st.composite
def op_shapes(draw):
    t = draw(st.sampled_from([4, 8, 16, 32, 64]))
    n = draw(st.integers(1, 24))
    m = draw(st.integers(1, 6))
    d = draw(st.integers(1, 5))
    return t, n, m, d


class TestConstraintOperator:
    @settings(**SETTINGS)
    @given(shapes=op_shapes(), seed=st.integers(0, 2**31 - 1))
    def test_forward_matches_ref(self, shapes, seed):
        t, n, m, d = shapes
        rng = np.random.default_rng(seed)
        act = (rng.random((t, n)) < 0.5).astype(np.float32)
        x, r = _rand(rng, n, m), _rand(rng, n, m, d)
        got = k_forward(act, x, r)
        want = ref.k_forward_ref(act, x, r)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(**SETTINGS)
    @given(shapes=op_shapes(), seed=st.integers(0, 2**31 - 1))
    def test_adjoint_matches_ref(self, shapes, seed):
        t, n, m, d = shapes
        rng = np.random.default_rng(seed)
        act = (rng.random((t, n)) < 0.5).astype(np.float32)
        y, r = _rand(rng, m, t, d), _rand(rng, n, m, d)
        got = k_adjoint(act, y, r)
        want = ref.k_adjoint_ref(act, y, r)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(**SETTINGS)
    @given(shapes=op_shapes(), seed=st.integers(0, 2**31 - 1))
    def test_adjointness(self, shapes, seed):
        """<K x, y> == <x, K^T y>: forward and adjoint are true transposes."""
        t, n, m, d = shapes
        rng = np.random.default_rng(seed)
        act = (rng.random((t, n)) < 0.5).astype(np.float32)
        x, r, y = _rand(rng, n, m), _rand(rng, n, m, d), _rand(rng, m, t, d)
        lhs = float(jnp.sum(k_forward(act, x, r) * y))
        rhs = float(jnp.sum(x * k_adjoint(act, y, r)))
        assert abs(lhs - rhs) <= 1e-3 * (1.0 + abs(lhs))


class TestPenaltyKernel:
    @settings(**SETTINGS)
    @given(n=st.integers(1, 40), m=st.integers(1, 8), d=st.integers(1, 6),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, m, d, seed):
        rng = np.random.default_rng(seed)
        dem = rng.uniform(0.0, 0.5, (n, d)).astype(np.float32)
        capinv = rng.uniform(1.0, 5.0, (m, d)).astype(np.float32)
        cost = rng.uniform(0.1, 3.0, m).astype(np.float32)
        p_avg, p_max, h_avg = penalty_scores(dem, capinv, cost)
        np.testing.assert_allclose(
            p_avg, ref.penalty_avg_ref(dem, capinv, cost), rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(
            p_max, ref.penalty_max_ref(dem, capinv, cost), rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(
            h_avg, ref.h_avg_ref(dem, capinv), rtol=2e-5, atol=2e-6)

    def test_avg_le_max_times_d(self):
        """h_avg <= h_max <= D * h_avg (sanity relation between policies)."""
        rng = np.random.default_rng(7)
        dem = rng.uniform(0, 0.5, (30, 4)).astype(np.float32)
        capinv = rng.uniform(1, 5, (5, 4)).astype(np.float32)
        cost = np.ones(5, np.float32)
        p_avg, p_max, _ = penalty_scores(dem, capinv, cost)
        assert np.all(np.asarray(p_avg) <= np.asarray(p_max) + 1e-6)
        assert np.all(np.asarray(p_max) <= 4 * np.asarray(p_avg) + 1e-6)
